//! The plan IR: one training step as a device-placed task DAG.
//!
//! Every parallelization strategy (`strategies.rs`) compiles to this IR.
//! Two consumers interpret a plan:
//!
//! * `sim::Engine` — timing: each step has a device, a cost annotation,
//!   and dependencies; the discrete-event simulator schedules it on the
//!   modeled 4-GPU node and reports the makespan (Table 3, Figure 4's
//!   wall clock).
//! * `parallel::exec::Executor` — numerics: steps run in emission order
//!   (builders emit in topological order by construction) against the
//!   PJRT artifact engine, producing real losses and gradients.
//!
//! Values flow through SSA-style *slots*. Activation slots have a home
//! device; when a step on another device reads one, the builder
//! auto-inserts a `Transfer` step — this is how the paper's Fig. 2/3
//! communication patterns arise mechanically from placement. Parameter
//! and input-data slots are *resident* (pre-distributed; no per-read
//! transfer cost), matching how frameworks keep weights on-device.

use crate::model_spec::OpCost;
use std::collections::BTreeMap;

/// Index of one SSA value in a [`Plan`] (written once, read many).
pub type Slot = usize;
/// Index of one [`Step`] in a [`Plan`]'s emission order.
pub type StepId = usize;

/// Pseudo-device for free host-side bookkeeping ops.
pub const HOST: usize = usize::MAX;

/// All-reduce algorithm — the cost difference between these two is the
/// paper's data-parallel bottleneck (§2.1) vs the hybrid strategy's cheap
/// attention-gradient sync (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceAlgo {
    /// NVLink ring among the participating devices.
    Ring,
    /// Staged through host memory (the MXNet-kvstore-like path the
    /// paper's data-parallel baseline pays for the full 142M parameters).
    HostStaged,
}

/// One operation. Reads/writes live on the owning [`Step`].
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Execute artifact `key` (reads = inputs in order, writes = outputs).
    Exec { key: String },
    /// Move one value `from` -> step.device over the link.
    Transfer { from: usize, bytes: f64 },
    /// Sum k replica slots into one result slot, synchronizing `devices`.
    AllReduce { devices: Vec<usize>, bytes: f64, n_arrays: usize, algo: ReduceAlgo },
    /// Fresh zero tensor of `shape`.
    Zeros { shape: Vec<usize> },
    /// Column `t` of an i32 `[B, T]` matrix -> `[B]`.
    ColI { t: usize },
    /// Column `t` of an f32 `[B, T]` matrix -> `[B]`.
    ColF { t: usize },
    /// Rows `[lo, hi)` of an f32 tensor (batch sharding).
    Slice0 { lo: usize, hi: usize },
    /// Rows `[lo, hi)` of an i32 tensor.
    SliceI0 { lo: usize, hi: usize },
    /// Concatenate f32 tensors along axis 0 (shard re-gather).
    Concat0,
    /// Concatenate two matrices along axis 1 (input-feeding `[emb ; Hc]`).
    Concat1,
    /// Split a matrix along axis 1 at `col` (two outputs).
    Split1 { col: usize },
    /// Stack `[B,h]` states over a new time axis -> `[B,T,h]`.
    StackTime,
    /// Time slice `t` of `[B,T,h]` -> `[B,h]`.
    TimeSlice { t: usize },
    /// Elementwise sum of the read slots (gradient accumulation).
    Add,
    /// Scalar sum of all elements (token counting).
    SumAll,
    /// Pass-through of reads[0] that additionally depends on the other
    /// reads — models a framework-level synchronization point (e.g. the
    /// vanilla per-step decoder loop of paper Fig. 2, where step t+1
    /// starts only after *all* of step t including the softmax).
    Gate,
}

/// One scheduled operation.
#[derive(Debug, Clone)]
pub struct Step {
    /// What to compute.
    pub op: Op,
    /// Device this step is placed on ([`HOST`] for bookkeeping ops).
    pub device: usize,
    /// Input slots, in the operand order the op expects.
    pub reads: Vec<Slot>,
    /// Output slots (SSA: each written exactly once, by this step).
    pub writes: Vec<Slot>,
    /// Compute cost (Exec / Add); comm ops are costed from their own
    /// fields by `sim::cost`.
    pub cost: OpCost,
    /// Dependencies: producer steps of every read slot.
    pub deps: Vec<StepId>,
}

/// Expected binding kind of an external input slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BindKind {
    /// Float tensor (activations, masks).
    F32,
    /// Integer tensor (token ids, lengths).
    I32,
}

/// A complete one-training-step program.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    /// Steps in emission order (topological by construction).
    pub steps: Vec<Step>,
    /// Total slot count (externals + every step output).
    pub n_slots: usize,
    /// Parameter name -> input slot.
    pub param_in: BTreeMap<String, Slot>,
    /// Data name ("src", "srclen", "tgt_in", "tgt_out", "tmask") -> slot.
    pub data_in: BTreeMap<String, (Slot, BindKind)>,
    /// Parameter name -> final summed-gradient slot.
    pub grad_out: BTreeMap<String, Slot>,
    /// Slot holding the summed token NLL.
    pub loss_out: Slot,
    /// Slot holding the target-token count.
    pub ntok_out: Slot,
    /// Last step index reading each slot (for executor memory reclaim).
    pub last_use: Vec<StepId>,
}

impl Plan {
    /// Total FLOPs across Exec steps (sanity checks, roofline reports).
    pub fn total_flops(&self) -> f64 {
        self.steps.iter().map(|s| s.cost.flops).sum()
    }

    /// Bytes crossing device links (transfers + all-reduce payloads).
    pub fn comm_bytes(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| match &s.op {
                Op::Transfer { bytes, .. } => *bytes,
                Op::AllReduce { bytes, .. } => *bytes,
                _ => 0.0,
            })
            .sum()
    }

    pub fn count_ops(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.steps.iter().filter(|s| pred(&s.op)).count()
    }

    /// Reverse map of [`Plan::grad_out`]: slot-indexed parameter name
    /// (None for non-gradient slots). The executors build this when a
    /// gradient sink is attached (`ExecOptions::grad_sink`), so a
    /// finished gradient can be pushed to its bucket the moment its
    /// producing step writes the slot — mid-execution, not after the
    /// whole plan drains. One `vec![None; n_slots]` fill per execution
    /// with O(1) lookup, so it stays invisible on the hot path.
    pub fn grad_names_by_slot(&self) -> Vec<Option<&str>> {
        let mut names = vec![None; self.n_slots];
        for (n, &s) in &self.grad_out {
            names[s] = Some(n.as_str());
        }
        names
    }

    /// Distinct devices steps are placed on (includes [`HOST`] when any
    /// host-side bookkeeping op exists). Sized worker pool of the
    /// parallel executor: one worker per entry.
    pub fn distinct_devices(&self) -> Vec<usize> {
        let mut devs: Vec<usize> = self.steps.iter().map(|s| s.device).collect();
        devs.sort_unstable();
        devs.dedup();
        devs
    }

    /// Validate SSA discipline + topological emission order.
    pub fn validate(&self) -> Result<(), String> {
        let mut written = vec![false; self.n_slots];
        for (s, _) in self.param_in.values().map(|s| (*s, ())) {
            written[s] = true;
        }
        for (s, _) in self.data_in.values() {
            written[*s] = true;
        }
        for (i, step) in self.steps.iter().enumerate() {
            for &r in &step.reads {
                if !written[r] {
                    return Err(format!("step {i} {:?} reads unwritten slot {r}", step.op));
                }
            }
            for &w in &step.writes {
                if written[w] {
                    return Err(format!("step {i} {:?} rewrites slot {w}", step.op));
                }
                written[w] = true;
            }
            for &d in &step.deps {
                if d >= i {
                    return Err(format!("step {i} depends on later step {d}"));
                }
            }
        }
        for (name, &g) in &self.grad_out {
            if !written[g] {
                return Err(format!("grad_out `{name}` slot {g} never written"));
            }
        }
        if !written[self.loss_out] || !written[self.ntok_out] {
            return Err("loss/ntok slot never written".into());
        }
        Ok(())
    }
}

/// Incremental plan builder with slot home-tracking and auto-transfers.
pub struct PlanBuilder {
    plan: Plan,
    /// Producer step of each slot (usize::MAX for external inputs).
    producer: Vec<StepId>,
    /// Home device of each slot; `HOST` for unplaced host data.
    home: Vec<usize>,
    /// Resident slots (params / input data): readable anywhere for free.
    resident: Vec<bool>,
    /// Per-device cache of already-transferred copies: (slot, dev) -> local slot.
    moved: BTreeMap<(Slot, usize), Slot>,
    /// Element count per slot when known (sizes transfers).
    pub numel: Vec<usize>,
}

impl PlanBuilder {
    pub fn new() -> Self {
        PlanBuilder {
            plan: Plan::default(),
            producer: Vec::new(),
            home: Vec::new(),
            resident: Vec::new(),
            moved: BTreeMap::new(),
            numel: Vec::new(),
        }
    }

    fn new_slot(&mut self, home: usize, resident: bool, numel: usize) -> Slot {
        let s = self.plan.n_slots;
        self.plan.n_slots += 1;
        self.producer.push(usize::MAX);
        self.home.push(home);
        self.resident.push(resident);
        self.numel.push(numel);
        s
    }

    /// Declare a parameter input (resident everywhere).
    pub fn param(&mut self, name: &str, numel: usize) -> Slot {
        if let Some(&s) = self.plan.param_in.get(name) {
            return s;
        }
        let s = self.new_slot(HOST, true, numel);
        self.plan.param_in.insert(name.to_string(), s);
        s
    }

    /// Declare a data input (resident: the loader pre-distributes it).
    pub fn data(&mut self, name: &str, kind: BindKind, numel: usize) -> Slot {
        if let Some(&(s, _)) = self.plan.data_in.get(name) {
            return s;
        }
        let s = self.new_slot(HOST, true, numel);
        self.plan.data_in.insert(name.to_string(), (s, kind));
        s
    }

    /// Resolve `slot` for a read on `dev`, inserting a Transfer if the
    /// value lives on another device (and caching the copy).
    fn use_on(&mut self, slot: Slot, dev: usize) -> Slot {
        if self.resident[slot] || dev == HOST || self.home[slot] == dev || self.home[slot] == HOST
        {
            return slot;
        }
        if let Some(&local) = self.moved.get(&(slot, dev)) {
            return local;
        }
        let bytes = self.numel[slot] as f64 * 4.0;
        let from = self.home[slot];
        let out = self.new_slot(dev, false, self.numel[slot]);
        self.push_raw(
            Op::Transfer { from, bytes },
            dev,
            vec![slot],
            vec![out],
            OpCost::ZERO,
        );
        self.moved.insert((slot, dev), out);
        out
    }

    fn push_raw(
        &mut self,
        op: Op,
        device: usize,
        reads: Vec<Slot>,
        writes: Vec<Slot>,
        cost: OpCost,
    ) -> StepId {
        let id = self.plan.steps.len();
        let deps: Vec<StepId> = reads
            .iter()
            .map(|&r| self.producer[r])
            .filter(|&p| p != usize::MAX)
            .collect();
        for &w in &writes {
            self.producer[w] = id;
        }
        self.plan.steps.push(Step { op, device, reads, writes, cost, deps });
        id
    }

    /// Emit a step whose reads are auto-localized to `device`; returns
    /// `n_out` fresh output slots homed on `device`.
    pub fn push(
        &mut self,
        op: Op,
        device: usize,
        reads: &[Slot],
        out_numels: &[usize],
        cost: OpCost,
    ) -> Vec<Slot> {
        let localized: Vec<Slot> = reads.iter().map(|&r| self.use_on(r, device)).collect();
        let writes: Vec<Slot> = out_numels
            .iter()
            .map(|&n| self.new_slot(device, false, n))
            .collect();
        self.push_raw(op, device, localized, writes.clone(), cost);
        writes
    }

    /// Exec helper: one output per manifest output.
    pub fn exec(
        &mut self,
        key: String,
        device: usize,
        reads: &[Slot],
        out_numels: &[usize],
        cost: OpCost,
    ) -> Vec<Slot> {
        self.push(Op::Exec { key }, device, reads, out_numels, cost)
    }

    /// Zero tensor (free, resident so it never needs transfers).
    pub fn zeros(&mut self, shape: &[usize]) -> Slot {
        let numel = shape.iter().product();
        let s = self.new_slot(HOST, true, numel);
        self.push_raw(Op::Zeros { shape: shape.to_vec() }, HOST, vec![], vec![s], OpCost::ZERO);
        s
    }

    /// Elementwise accumulate: `acc + x` on `device` (memory-bound cost).
    pub fn add(&mut self, acc: Slot, x: Slot, device: usize) -> Slot {
        let n = self.numel[acc].max(self.numel[x]);
        let cost = OpCost { flops: n as f64, bytes: 3.0 * n as f64 * 4.0, batch: 0 };
        self.push(Op::Add, device, &[acc, x], &[n], cost)[0]
    }

    /// All-reduce (sum) one gradient array across replicas.
    pub fn allreduce(
        &mut self,
        parts: &[Slot],
        devices: Vec<usize>,
        algo: ReduceAlgo,
    ) -> Slot {
        let numel = self.numel[parts[0]];
        let bytes = numel as f64 * 4.0;
        let dev0 = devices[0];
        let out = self.new_slot(HOST, true, numel); // result broadcast everywhere
        let localized: Vec<Slot> = parts.to_vec();
        self.push_raw(
            Op::AllReduce { devices, bytes, n_arrays: 1, algo },
            dev0,
            localized,
            vec![out],
            OpCost::ZERO,
        );
        out
    }

    pub fn numel_of(&self, s: Slot) -> usize {
        self.numel[s]
    }

    /// Finish: record outputs, compute last-use, validate.
    pub fn finish(
        mut self,
        grad_out: BTreeMap<String, Slot>,
        loss_out: Slot,
        ntok_out: Slot,
    ) -> Plan {
        self.plan.grad_out = grad_out;
        self.plan.loss_out = loss_out;
        self.plan.ntok_out = ntok_out;
        let mut last_use = vec![usize::MAX; self.plan.n_slots];
        for (i, step) in self.plan.steps.iter().enumerate() {
            for &r in &step.reads {
                last_use[r] = i;
            }
        }
        // Outputs survive to the end.
        let end = self.plan.steps.len();
        for &s in self
            .plan
            .grad_out
            .values()
            .chain([&self.plan.loss_out, &self.plan.ntok_out])
        {
            last_use[s] = end;
        }
        self.plan.last_use = last_use;
        debug_assert_eq!(self.plan.validate(), Ok(()));
        self.plan
    }
}

impl Default for PlanBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_transfer_inserted_once_per_device() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 4);
        let x = b.exec("f".into(), 0, &[p], &[4], OpCost::ZERO)[0];
        // Two consumers on device 1: only one transfer.
        b.exec("g".into(), 1, &[x], &[4], OpCost::ZERO);
        b.exec("h".into(), 1, &[x], &[4], OpCost::ZERO);
        let plan = b.finish(BTreeMap::new(), p, p);
        let transfers = plan.count_ops(|o| matches!(o, Op::Transfer { .. }));
        assert_eq!(transfers, 1);
    }

    #[test]
    fn same_device_read_needs_no_transfer() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 4);
        let x = b.exec("f".into(), 0, &[p], &[4], OpCost::ZERO)[0];
        b.exec("g".into(), 0, &[x], &[4], OpCost::ZERO);
        let plan = b.finish(BTreeMap::new(), p, p);
        assert_eq!(plan.count_ops(|o| matches!(o, Op::Transfer { .. })), 0);
    }

    #[test]
    fn deps_follow_slot_producers() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        let a = b.exec("f".into(), 0, &[p], &[1], OpCost::ZERO)[0];
        let c = b.exec("g".into(), 0, &[a], &[1], OpCost::ZERO)[0];
        let plan = b.finish(BTreeMap::new(), c, c);
        assert_eq!(plan.steps[1].deps, vec![0]);
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn validate_catches_use_before_def() {
        let plan = Plan {
            steps: vec![Step {
                op: Op::Add,
                device: 0,
                reads: vec![0],
                writes: vec![1],
                cost: OpCost::ZERO,
                deps: vec![],
            }],
            n_slots: 2,
            ..Default::default()
        };
        assert!(plan.validate().is_err());
    }

    #[test]
    fn distinct_devices_cover_placement() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 4);
        let x = b.exec("f".into(), 0, &[p], &[4], OpCost::ZERO)[0];
        b.exec("g".into(), 2, &[x], &[4], OpCost::ZERO);
        let plan = b.finish(BTreeMap::new(), p, p);
        // Device 2 plus the auto-transfer's target; sorted and deduped.
        let devs = plan.distinct_devices();
        assert!(devs.contains(&0) && devs.contains(&2));
        assert!(devs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn resident_params_never_transfer() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1000);
        b.exec("f".into(), 0, &[p], &[1], OpCost::ZERO);
        b.exec("g".into(), 3, &[p], &[1], OpCost::ZERO);
        let plan = b.finish(BTreeMap::new(), p, p);
        assert_eq!(plan.count_ops(|o| matches!(o, Op::Transfer { .. })), 0);
    }
}
