//! Parallelization strategies: plan IR, replica graph construction, the
//! five strategy planners (Table 3), and the real-numerics executor.

pub mod exec;
pub mod plan;
pub mod replica;
pub mod strategies;

pub use exec::{
    execute, execute_with, run_sharded, Batch, ExecMode, ExecOptions, GradSink, StepOut, Value,
};
pub use plan::{Op, Plan, PlanBuilder, ReduceAlgo, Slot};
pub use replica::{AttnMode, ReplicaSpec};
pub use strategies::build_plan;
