//! Forward + backward plan construction for one model replica.
//!
//! This is where the paper's Figures 1-3 become code. One call to
//! [`build_replica`] emits the complete fwd+bwd task graph of the
//! attention-based seq2seq model for one replica under a given
//! placement / input-feeding / attention-mode combination:
//!
//! * encoder: always a wavefront — cell `(l, t)` depends on `(l-1, t)`
//!   and `(l, t-1)` only (the paper's upward-right green arrows), so
//!   layers pinned to different devices pipeline;
//! * decoder without input-feeding (HybridNMT): the same wavefront;
//! * decoder with input-feeding (baseline / HybridNMTIF): cell `(0, t)`
//!   additionally reads the attention output of step `t-1`, which
//!   serializes the decoder across the whole device chain — exactly the
//!   dependency the paper removes;
//! * attention-softmax: per-step on one device (Fig. 2), per-step
//!   batch-sharded (HybridNMTIF), or once-per-batch batch-sharded over
//!   all devices (Fig. 3, HybridNMT).
//!
//! The backward pass is the mirrored wavefront with gradient
//! accumulation on each layer's owning device — model-parallel layers
//! never synchronize parameters; only the attention part all-reduces
//! (ring for the hybrid strategies, host-staged for full data
//! parallelism, handled by `strategies.rs`).

use super::plan::{BindKind, Op, PlanBuilder, ReduceAlgo, Slot, HOST};
use crate::config::ModelDims;
use crate::model_spec::{
    attn_block_cost, attn_ctx_bwd_cost, attn_ctx_fwd_cost, attn_out_bwd_cost,
    attn_out_fwd_cost, cell_din, embed_bwd_cost, embed_fwd_cost, lstm_cell_bwd_cost,
    lstm_cell_fwd_cost, OpCost, Placement,
};
use crate::runtime::keys;
use std::collections::BTreeMap;

/// How the attention-softmax part is parallelized.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AttnMode {
    /// All steps' attention on one device, one step at a time (Fig. 2).
    StepLocal { device: usize },
    /// Per-step attention batch-sharded over devices (HybridNMTIF).
    StepSharded { devices: Vec<usize> },
    /// One fused block over all steps, batch-sharded (Fig. 3, HybridNMT).
    /// Requires input-feeding removed.
    BlockSharded { devices: Vec<usize> },
}

/// One replica's specification.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub dims: ModelDims,
    /// This replica's batch size `b` (artifacts must exist at this size).
    pub batch: usize,
    /// Rows `[lo, hi)` of the global batch this replica consumes.
    pub batch_range: (usize, usize),
    pub placement: Placement,
    pub input_feeding: bool,
    pub attn: AttnMode,
}

/// Slots a replica exposes to the strategy layer.
pub struct ReplicaOut {
    pub loss: Slot,
    pub ntok: Slot,
    /// Parameter name -> this replica's summed gradient slot.
    pub grads: BTreeMap<String, Slot>,
}

const ATTN_PARAM_NAMES: [&str; 4] = ["attn_Wa", "attn_Wc", "attn_Wout", "attn_bout"];

/// Gradient accumulator: the first contribution seeds the slot, later
/// ones chain `Add` steps on the owning device.
///
/// The slot this converges to per parameter becomes the plan's
/// `grad_out` entry — the exact point the executors' streaming
/// [`GradSink`](super::exec::GradSink) notification fires, so a
/// parameter whose accumulation chain finishes early in the backward
/// pass enters the cross-shard bucket reduce while later layers are
/// still computing.
struct Accum {
    slots: BTreeMap<String, (Slot, usize)>,
}

impl Accum {
    fn new() -> Self {
        Accum { slots: BTreeMap::new() }
    }

    fn add(&mut self, b: &mut PlanBuilder, name: &str, slot: Slot, dev: usize) {
        // Chain in place: only the first contribution allocates the key
        // (the seed remove+reinsert pattern re-allocated the name on
        // every accumulation step of every plan build).
        match self.slots.get_mut(name) {
            None => {
                self.slots.insert(name.into(), (slot, dev));
            }
            Some(entry) => {
                entry.0 = b.add(entry.0, slot, entry.1);
            }
        }
    }

    fn get(&self, name: &str) -> Slot {
        self.slots[name].0
    }

    fn into_grads(self) -> BTreeMap<String, Slot> {
        self.slots.into_iter().map(|(k, (s, _))| (k, s)).collect()
    }
}

/// Per-replica view of the input data (sliced rows of the global batch).
struct DataSlots {
    src: Slot,
    srclen: Slot,
    tgt_in: Slot,
    tgt_out: Slot,
    tmask: Slot,
}

/// Parameter slots of one replica.
struct Params {
    src_emb: Slot,
    tgt_emb: Slot,
    /// `[side][layer]` fused weights / biases (side 0 = enc, 1 = dec).
    w: Vec<Vec<Slot>>,
    b: Vec<Vec<Slot>>,
    wa: Slot,
    wc: Slot,
    wout: Slot,
    bout: Slot,
}

/// Saved forward state of one LSTM stack (for the recompute backward).
struct StackTrace {
    /// x input of cell (l, t).
    x: Vec<Vec<Slot>>,
    /// h entering cell (l, t) — i.e. `h_{l, t-1}`.
    h_in: Vec<Vec<Slot>>,
    c_in: Vec<Vec<Slot>>,
    /// Top-layer outputs per t.
    tops: Vec<Slot>,
    /// ids column per t (for embed_bwd).
    ids: Vec<Slot>,
}

impl StackTrace {
    fn new(layers: usize) -> Self {
        StackTrace {
            x: vec![Vec::new(); layers],
            h_in: vec![Vec::new(); layers],
            c_in: vec![Vec::new(); layers],
            tops: Vec::new(),
            ids: Vec::new(),
        }
    }
}

/// dh/dc flowing backward in time, per layer.
struct BwdState {
    dh: Vec<Slot>,
    dc: Vec<Slot>,
}

impl BwdState {
    fn zeros(b: &mut PlanBuilder, layers: usize, bt: usize, h: usize) -> Self {
        BwdState {
            dh: (0..layers).map(|_| b.zeros(&[bt, h])).collect(),
            dc: (0..layers).map(|_| b.zeros(&[bt, h])).collect(),
        }
    }
}

struct Ctx<'a> {
    d: ModelDims,
    bt: usize,
    pl: &'a Placement,
    input_feeding: bool,
}

fn slice_i(b: &mut PlanBuilder, s: Slot, lo: usize, hi: usize, row: usize) -> Slot {
    b.push(Op::SliceI0 { lo, hi }, HOST, &[s], &[(hi - lo) * row], OpCost::ZERO)[0]
}

fn slice_f(b: &mut PlanBuilder, s: Slot, lo: usize, hi: usize, row: usize, home: usize) -> Slot {
    b.push(Op::Slice0 { lo, hi }, home, &[s], &[(hi - lo) * row], OpCost::ZERO)[0]
}

fn col_i(b: &mut PlanBuilder, s: Slot, t: usize, bt: usize) -> Slot {
    b.push(Op::ColI { t }, HOST, &[s], &[bt], OpCost::ZERO)[0]
}

fn col_f(b: &mut PlanBuilder, s: Slot, t: usize, bt: usize) -> Slot {
    b.push(Op::ColF { t }, HOST, &[s], &[bt], OpCost::ZERO)[0]
}

/// Embed + run the stacked LSTM forward for one timestep; returns the
/// top-layer output. `x_override` replaces the embedding as the first
/// layer's input (input-feeding concat, built by the caller).
#[allow(clippy::too_many_arguments)]
fn stack_fwd_step(
    b: &mut PlanBuilder,
    cx: &Ctx,
    p: &Params,
    side: usize, // 0 = enc, 1 = dec
    tr: &mut StackTrace,
    ids_mat: Slot,
    t: usize,
    h_prev: &mut [Slot],
    c_prev: &mut [Slot],
    hc_prev: Option<Slot>,
) -> Slot {
    let (d, bt) = (&cx.d, cx.bt);
    let dec_side = side == 1;
    let iff = cx.input_feeding && dec_side;
    let ids_t = col_i(b, ids_mat, t, bt);
    tr.ids.push(ids_t);
    let emb_param = if dec_side { p.tgt_emb } else { p.src_emb };
    let emb = b.exec(
        keys::embed_fwd(bt),
        cx.pl.emb,
        &[emb_param, ids_t],
        &[bt * d.d],
        embed_fwd_cost(d, bt),
    )[0];
    let mut x = if iff {
        let hc = hc_prev.expect("input feeding needs hc_prev");
        b.push(
            Op::Concat1,
            cx.pl.device_of_layer(0),
            &[emb, hc],
            &[bt * (d.d + d.h)],
            OpCost::ZERO,
        )[0]
    } else {
        emb
    };
    for l in 0..d.layers {
        let din = cell_din(d, dec_side, l, cx.input_feeding);
        let dev = cx.pl.device_of_layer(l);
        tr.x[l].push(x);
        tr.h_in[l].push(h_prev[l]);
        tr.c_in[l].push(c_prev[l]);
        let hc = b.exec(
            keys::lstm_cell_fwd(din, bt),
            dev,
            &[p.w[side][l], p.b[side][l], x, h_prev[l], c_prev[l]],
            &[bt * d.h, bt * d.h],
            lstm_cell_fwd_cost(d, bt, din),
        );
        h_prev[l] = hc[0];
        c_prev[l] = hc[1];
        x = hc[0];
    }
    tr.tops.push(x);
    x
}

/// Backward through the stacked LSTM for one timestep.
///
/// `dh_top_extra` is the gradient arriving at the top layer from the
/// attention part. Returns `Some(dhc)` — the input-feeding gradient for
/// step `t-1` — when `if_split_col` is set.
#[allow(clippy::too_many_arguments)]
fn stack_bwd_step(
    b: &mut PlanBuilder,
    cx: &Ctx,
    p: &Params,
    side: usize,
    tr: &StackTrace,
    grads: &mut Accum,
    t: usize,
    dh_top_extra: Slot,
    st: &mut BwdState,
    if_split_col: Option<usize>,
) -> Option<Slot> {
    let (d, bt) = (&cx.d, cx.bt);
    let dec_side = side == 1;
    let side_name = if dec_side { "dec" } else { "enc" };
    let mut dx_from_above: Option<Slot> = None;
    for l in (0..d.layers).rev() {
        let dev = cx.pl.device_of_layer(l);
        let incoming = if l == d.layers - 1 { dh_top_extra } else { dx_from_above.unwrap() };
        let dh_in = b.add(st.dh[l], incoming, dev);
        let din = cell_din(d, dec_side, l, cx.input_feeding);
        let outs = b.exec(
            keys::lstm_cell_bwd(din, bt),
            dev,
            &[
                p.w[side][l],
                p.b[side][l],
                tr.x[l][t],
                tr.h_in[l][t],
                tr.c_in[l][t],
                dh_in,
                st.dc[l],
            ],
            &[
                (din + d.h) * 4 * d.h,
                4 * d.h,
                bt * din,
                bt * d.h,
                bt * d.h,
            ],
            lstm_cell_bwd_cost(d, bt, din),
        );
        grads.add(b, &format!("{side_name}_l{l}_W"), outs[0], dev);
        grads.add(b, &format!("{side_name}_l{l}_b"), outs[1], dev);
        dx_from_above = Some(outs[2]);
        st.dh[l] = outs[3];
        st.dc[l] = outs[4];
    }
    let dx0 = dx_from_above.unwrap();
    let (demb, dhc) = match if_split_col {
        Some(col) => {
            let parts = b.push(
                Op::Split1 { col },
                cx.pl.device_of_layer(0),
                &[dx0],
                &[bt * col, bt * d.h],
                OpCost::ZERO,
            );
            (parts[0], Some(parts[1]))
        }
        None => (dx0, None),
    };
    let emb_name = if dec_side { "tgt_emb" } else { "src_emb" };
    let de = b.exec(
        keys::embed_bwd(bt),
        cx.pl.emb,
        &[tr.ids[t], demb],
        &[d.vocab * d.d],
        embed_bwd_cost(d, bt),
    )[0];
    grads.add(b, emb_name, de, cx.pl.emb);
    dhc
}

/// Build the complete fwd+bwd replica graph. `global_batch` is the size
/// of the bound data tensors; the replica slices `batch_range` out.
pub fn build_replica(b: &mut PlanBuilder, spec: &ReplicaSpec, global_batch: usize) -> ReplicaOut {
    let d = spec.dims.clone();
    let bt = spec.batch;
    assert_eq!(spec.batch_range.1 - spec.batch_range.0, bt);
    if matches!(spec.attn, AttnMode::BlockSharded { .. }) {
        assert!(!spec.input_feeding, "block attention requires input-feeding removed");
    } else {
        assert!(spec.input_feeding, "per-step attention modes model the input-feeding baselines");
    }
    let cx = Ctx { d: d.clone(), bt, pl: &spec.placement, input_feeding: spec.input_feeding };

    // ---- data (sliced to this replica's rows)
    let data = {
        let (m, n) = (d.max_src, d.max_tgt);
        let src = b.data("src", BindKind::I32, global_batch * m);
        let srclen = b.data("srclen", BindKind::I32, global_batch);
        let tgt_in = b.data("tgt_in", BindKind::I32, global_batch * n);
        let tgt_out = b.data("tgt_out", BindKind::I32, global_batch * n);
        let tmask = b.data("tmask", BindKind::F32, global_batch * n);
        let (lo, hi) = spec.batch_range;
        if (lo, hi) == (0, global_batch) {
            DataSlots { src, srclen, tgt_in, tgt_out, tmask }
        } else {
            DataSlots {
                src: slice_i(b, src, lo, hi, m),
                srclen: slice_i(b, srclen, lo, hi, 1),
                tgt_in: slice_i(b, tgt_in, lo, hi, n),
                tgt_out: slice_i(b, tgt_out, lo, hi, n),
                tmask: slice_f(b, tmask, lo, hi, n, HOST),
            }
        }
    };

    // ---- parameters (resident)
    let p = {
        let mut w = Vec::new();
        let mut bs = Vec::new();
        for dec in [false, true] {
            let side = if dec { "dec" } else { "enc" };
            let mut ws = Vec::new();
            let mut bb = Vec::new();
            for l in 0..d.layers {
                let din = cell_din(&d, dec, l, spec.input_feeding);
                ws.push(b.param(&format!("{side}_l{l}_W"), (din + d.h) * 4 * d.h));
                bb.push(b.param(&format!("{side}_l{l}_b"), 4 * d.h));
            }
            w.push(ws);
            bs.push(bb);
        }
        Params {
            src_emb: b.param("src_emb", d.vocab * d.d),
            tgt_emb: b.param("tgt_emb", d.vocab * d.d),
            w,
            b: bs,
            wa: b.param("attn_Wa", d.h * d.h),
            wc: b.param("attn_Wc", 2 * d.h * d.h),
            wout: b.param("attn_Wout", d.h * d.vocab),
            bout: b.param("attn_bout", d.vocab),
        }
    };

    let mut grads = Accum::new();
    let mut loss_parts: Vec<Slot> = Vec::new();

    // ------------------------------------------------------- encoder fwd
    let mut enc = StackTrace::new(d.layers);
    {
        let mut h: Vec<Slot> = (0..d.layers).map(|_| b.zeros(&[bt, d.h])).collect();
        let mut c: Vec<Slot> = (0..d.layers).map(|_| b.zeros(&[bt, d.h])).collect();
        for t in 0..d.max_src {
            stack_fwd_step(b, &cx, &p, 0, &mut enc, data.src, t, &mut h, &mut c, None);
        }
    }
    // S: stacked encoder states on the state-home device (Fig. 3: "GPU 3
    // stores the hidden states of all steps").
    let s_block = {
        let tops = enc.tops.clone();
        b.push(Op::StackTime, cx.pl.state_home, &tops, &[bt * d.max_src * d.h], OpCost::ZERO)[0]
    };

    // --------------------------------------- decoder fwd+bwd + attention
    // Produces: loss parts, ntok, dS (gradient flowing into the encoder
    // backward), and fills `grads` with decoder + attention gradients.
    let (ds_block, ntok) = match &spec.attn {
        AttnMode::BlockSharded { devices } => {
            // (1) wavefront decoder forward
            let mut dec = StackTrace::new(d.layers);
            {
                let mut h: Vec<Slot> = (0..d.layers).map(|_| b.zeros(&[bt, d.h])).collect();
                let mut c: Vec<Slot> = (0..d.layers).map(|_| b.zeros(&[bt, d.h])).collect();
                for t in 0..d.max_tgt {
                    stack_fwd_step(b, &cx, &p, 1, &mut dec, data.tgt_in, t, &mut h, &mut c, None);
                }
            }
            let tops = dec.tops.clone();
            let h_block =
                b.push(Op::StackTime, cx.pl.state_home, &tops, &[bt * d.max_tgt * d.h], OpCost::ZERO)[0];

            // (2) data-parallel fused attention block per shard
            let g = devices.len();
            let bs = bt / g;
            assert_eq!(bs * g, bt, "batch {bt} not divisible into {g} shards");
            let mut ds_parts = Vec::new();
            let mut dh_parts = Vec::new();
            let mut agp: Vec<[Slot; 4]> = Vec::new();
            let mut ntok_parts = Vec::new();
            for (gi, &dev) in devices.iter().enumerate() {
                let (lo, hi) = (gi * bs, (gi + 1) * bs);
                let sh = slice_f(b, s_block, lo, hi, d.max_src * d.h, cx.pl.state_home);
                let hh = slice_f(b, h_block, lo, hi, d.max_tgt * d.h, cx.pl.state_home);
                let sl = slice_i(b, data.srclen, lo, hi, 1);
                let tg = slice_i(b, data.tgt_out, lo, hi, d.max_tgt);
                let tm = slice_f(b, data.tmask, lo, hi, d.max_tgt, HOST);
                let outs = b.exec(
                    keys::attn_block(bs),
                    dev,
                    &[p.wa, p.wc, p.wout, p.bout, sh, hh, sl, tg, tm],
                    &[
                        1,
                        1,
                        d.h * d.h,
                        2 * d.h * d.h,
                        d.h * d.vocab,
                        d.vocab,
                        bs * d.max_src * d.h,
                        bs * d.max_tgt * d.h,
                    ],
                    attn_block_cost(&d, bs, d.max_tgt),
                );
                loss_parts.push(outs[0]);
                ntok_parts.push(outs[1]);
                agp.push([outs[2], outs[3], outs[4], outs[5]]);
                ds_parts.push(outs[6]);
                dh_parts.push(outs[7]);
            }
            // Ring all-reduce of the small attention gradients — the only
            // parameter sync HybridNMT pays (paper §3.2).
            for (i, name) in ATTN_PARAM_NAMES.iter().enumerate() {
                let parts: Vec<Slot> = agp.iter().map(|x| x[i]).collect();
                let red = b.allreduce(&parts, devices.clone(), ReduceAlgo::Ring);
                grads.add(b, name, red, devices[0]);
            }
            let ds = b.push(Op::Concat0, cx.pl.state_home, &ds_parts, &[bt * d.max_src * d.h], OpCost::ZERO)[0];
            let dh = b.push(Op::Concat0, cx.pl.state_home, &dh_parts, &[bt * d.max_tgt * d.h], OpCost::ZERO)[0];
            let mut nt = ntok_parts[0];
            for &x in &ntok_parts[1..] {
                nt = b.add(nt, x, HOST);
            }

            // (3) wavefront decoder backward (mirrored green arrows)
            let mut st = BwdState::zeros(b, d.layers, bt, d.h);
            for t in (0..d.max_tgt).rev() {
                let dh_top =
                    b.push(Op::TimeSlice { t }, cx.pl.state_home, &[dh], &[bt * d.h], OpCost::ZERO)[0];
                stack_bwd_step(b, &cx, &p, 1, &dec, &mut grads, t, dh_top, &mut st, None);
            }
            (ds, nt)
        }

        AttnMode::StepLocal { .. } | AttnMode::StepSharded { .. } => {
            let devices: Vec<usize> = match &spec.attn {
                AttnMode::StepLocal { device } => vec![*device],
                AttnMode::StepSharded { devices } => devices.clone(),
                _ => unreachable!(),
            };
            let g = devices.len();
            let bs = bt / g;
            assert_eq!(bs * g, bt);
            // S and srclen scattered to the shard devices once.
            let s_shards: Vec<Slot> = (0..g)
                .map(|gi| {
                    if g == 1 {
                        s_block
                    } else {
                        slice_f(b, s_block, gi * bs, (gi + 1) * bs, d.max_src * d.h, cx.pl.state_home)
                    }
                })
                .collect();
            let len_shards: Vec<Slot> = (0..g)
                .map(|gi| {
                    if g == 1 {
                        data.srclen
                    } else {
                        slice_i(b, data.srclen, gi * bs, (gi + 1) * bs, 1)
                    }
                })
                .collect();

            // (1) decoder forward with per-step attention, threading Hc.
            // step_rec[t][gi] = (device, Hc shard, tgt shard, tmask shard, h_top shard)
            let mut step_rec: Vec<Vec<(usize, Slot, Slot, Slot, Slot)>> = Vec::new();
            let mut dec = StackTrace::new(d.layers);
            let mut htops: Vec<Slot> = Vec::new();
            let top_dev = cx.pl.device_of_layer(d.layers - 1);
            {
                let mut h: Vec<Slot> = (0..d.layers).map(|_| b.zeros(&[bt, d.h])).collect();
                let mut c: Vec<Slot> = (0..d.layers).map(|_| b.zeros(&[bt, d.h])).collect();
                let mut hc_prev = b.zeros(&[bt, d.h]);
                for t in 0..d.max_tgt {
                    let top = stack_fwd_step(
                        b, &cx, &p, 1, &mut dec, data.tgt_in, t, &mut h, &mut c, Some(hc_prev),
                    );
                    htops.push(top);
                    let tgt_t = col_i(b, data.tgt_out, t, bt);
                    let tm_t = col_f(b, data.tmask, t, bt);
                    let mut hc_parts = Vec::new();
                    let mut shard_rec = Vec::new();
                    for (gi, &dev) in devices.iter().enumerate() {
                        let (lo, hi) = (gi * bs, (gi + 1) * bs);
                        let (xt, tg, tmg) = if g == 1 {
                            (top, tgt_t, tm_t)
                        } else {
                            (
                                slice_f(b, top, lo, hi, d.h, top_dev),
                                slice_i(b, tgt_t, lo, hi, 1),
                                slice_f(b, tm_t, lo, hi, 1, HOST),
                            )
                        };
                        // Critical-path half only: context + Hc. The bulky
                        // output projection is emitted *after* the loop so
                        // the scheduler backfills it into recurrence stalls
                        // (the paper's HybridNMTIF would be barely faster
                        // than model parallelism otherwise).
                        let outs = b.exec(
                            keys::attn_ctx_fwd(bs),
                            dev,
                            &[p.wa, p.wc, s_shards[gi], len_shards[gi], xt],
                            &[bs * d.h],
                            attn_ctx_fwd_cost(&d, bs),
                        );
                        if g == 1 {
                            // Vanilla-framework schedule (baseline / DP /
                            // MP rows): the output projection stays on the
                            // critical path (paper Fig. 2 — step t+1 waits
                            // for *all* of step t), expressed by gating the
                            // Hc hand-off on the loss step.
                            let lo = b.exec(
                                keys::attn_out_fwd(bs),
                                dev,
                                &[p.wout, p.bout, outs[0], tg, tmg],
                                &[1],
                                attn_out_fwd_cost(&d, bs),
                            );
                            loss_parts.push(lo[0]);
                            let gated = b.push(
                                Op::Gate,
                                dev,
                                &[outs[0], lo[0]],
                                &[bs * d.h],
                                OpCost::ZERO,
                            )[0];
                            hc_parts.push(gated);
                        } else {
                            hc_parts.push(outs[0]);
                        }
                        shard_rec.push((dev, outs[0], tg, tmg, xt));
                    }
                    step_rec.push(shard_rec);
                    hc_prev = if g == 1 {
                        hc_parts[0]
                    } else {
                        b.push(
                            Op::Concat0,
                            cx.pl.device_of_layer(0),
                            &hc_parts,
                            &[bt * d.h],
                            OpCost::ZERO,
                        )[0]
                    };
                }
            }

            // (1b) deferred output projections + losses (sharded modes
            // only — the paper's own HybridNMTIF implementation): emitted
            // after the recurrence so their larger plan ids make them
            // backfill the recurrence stalls.
            for shard_rec in step_rec.iter().filter(|_| g > 1) {
                for &(dev, hc, tg, tmg, _xt) in shard_rec {
                    let outs = b.exec(
                        keys::attn_out_fwd(bs),
                        dev,
                        &[p.wout, p.bout, hc, tg, tmg],
                        &[1],
                        attn_out_fwd_cost(&d, bs),
                    );
                    loss_parts.push(outs[0]);
                }
            }

            // (2a) out-projection backward: depends only on forward
            // values, so all (t, shard) instances are schedulable the
            // moment the forward finishes — emitted before the serial
            // reverse chain, they flood the devices in parallel.
            // dhc_loss[t][gi] feeds the chain below.
            let mut dhc_loss: Vec<Vec<Slot>> = Vec::new();
            let mut attn_acc: Vec<Accum> = (0..g).map(|_| Accum::new()).collect();
            for shard_rec in step_rec.iter().filter(|_| g > 1) {
                let mut row = Vec::new();
                for (gi, &(dev, hc, tg, tmg, _xt)) in shard_rec.iter().enumerate() {
                    let outs = b.exec(
                        keys::attn_out_bwd(bs),
                        dev,
                        &[p.wout, p.bout, hc, tg, tmg],
                        &[d.h * d.vocab, d.vocab, bs * d.h],
                        attn_out_bwd_cost(&d, bs),
                    );
                    attn_acc[gi].add(b, "attn_Wout", outs[0], dev);
                    attn_acc[gi].add(b, "attn_bout", outs[1], dev);
                    row.push(outs[2]);
                }
                dhc_loss.push(row);
            }

            // (2b) serial reverse chain: ctx backward + LSTM backward,
            // threading the input-feeding cotangent dHc. Only the small
            // context GEMMs sit on this chain; the h x V work was all
            // emitted above.
            let mut st = BwdState::zeros(b, d.layers, bt, d.h);
            let mut ds_acc: Vec<Option<Slot>> = vec![None; g];
            let mut dhc_next = b.zeros(&[bt, d.h]); // dL/dHc_{N-1} = 0
            for t in (0..d.max_tgt).rev() {
                let mut dhtop_parts = Vec::new();
                for (gi, &dev) in devices.iter().enumerate() {
                    let (lo, hi) = (gi * bs, (gi + 1) * bs);
                    let (_, hc, tg, tmg, xt) = step_rec[t][gi];
                    let dhcg = if g == 1 {
                        dhc_next
                    } else {
                        slice_f(b, dhc_next, lo, hi, d.h, cx.pl.device_of_layer(0))
                    };
                    // Loss-side Hc cotangent: precomputed (sharded modes,
                    // backfilled) or emitted inline on the chain (vanilla).
                    let dhc_l = if g == 1 {
                        let outs = b.exec(
                            keys::attn_out_bwd(bs),
                            dev,
                            &[p.wout, p.bout, hc, tg, tmg],
                            &[d.h * d.vocab, d.vocab, bs * d.h],
                            attn_out_bwd_cost(&d, bs),
                        );
                        attn_acc[gi].add(b, "attn_Wout", outs[0], dev);
                        attn_acc[gi].add(b, "attn_bout", outs[1], dev);
                        outs[2]
                    } else {
                        dhc_loss[t][gi]
                    };
                    // Total Hc cotangent = loss side + input-feeding side.
                    let dhc_total = b.add(dhc_l, dhcg, dev);
                    let outs = b.exec(
                        keys::attn_ctx_bwd(bs),
                        dev,
                        &[p.wa, p.wc, s_shards[gi], len_shards[gi], xt, dhc_total],
                        &[
                            d.h * d.h,
                            2 * d.h * d.h,
                            bs * d.max_src * d.h,
                            bs * d.h,
                        ],
                        attn_ctx_bwd_cost(&d, bs),
                    );
                    attn_acc[gi].add(b, "attn_Wa", outs[0], dev);
                    attn_acc[gi].add(b, "attn_Wc", outs[1], dev);
                    ds_acc[gi] = Some(match ds_acc[gi] {
                        None => outs[2],
                        Some(acc) => b.add(acc, outs[2], dev),
                    });
                    dhtop_parts.push(outs[3]);
                }
                let dh_top = if g == 1 {
                    dhtop_parts[0]
                } else {
                    b.push(Op::Concat0, top_dev, &dhtop_parts, &[bt * d.h], OpCost::ZERO)[0]
                };
                // LSTM backward for step t; its first-layer dx carries the
                // dHc cotangent for step t-1 (the input-feeding edge).
                let dhc = stack_bwd_step(
                    b, &cx, &p, 1, &dec, &mut grads, t, dh_top, &mut st, Some(d.d),
                );
                dhc_next = dhc.expect("IF split requested");
            }

            // Attention parameter gradients: local accumulation, then one
            // ring all-reduce across shard devices (HybridNMTIF) or a
            // plain move into the grad map (single device).
            if g == 1 {
                for name in ATTN_PARAM_NAMES {
                    let s = attn_acc[0].get(name);
                    grads.add(b, name, s, devices[0]);
                }
            } else {
                for name in ATTN_PARAM_NAMES {
                    let parts: Vec<Slot> = attn_acc.iter().map(|a| a.get(name)).collect();
                    let red = b.allreduce(&parts, devices.clone(), ReduceAlgo::Ring);
                    grads.add(b, name, red, devices[0]);
                }
            }
            let ds = if g == 1 {
                ds_acc[0].unwrap()
            } else {
                let parts: Vec<Slot> = ds_acc.iter().map(|x| x.unwrap()).collect();
                b.push(Op::Concat0, cx.pl.state_home, &parts, &[bt * d.max_src * d.h], OpCost::ZERO)[0]
            };
            let nt = b.push(Op::SumAll, HOST, &[data.tmask], &[1], OpCost::ZERO)[0];
            (ds, nt)
        }
    };

    // ------------------------------------------------------ encoder bwd
    {
        let mut st = BwdState::zeros(b, d.layers, bt, d.h);
        for t in (0..d.max_src).rev() {
            let dh_top =
                b.push(Op::TimeSlice { t }, cx.pl.state_home, &[ds_block], &[bt * d.h], OpCost::ZERO)[0];
            stack_bwd_step(b, &cx, &p, 0, &enc, &mut grads, t, dh_top, &mut st, None);
        }
    }

    // ------------------------------------------------------------- loss
    let mut loss = loss_parts[0];
    for &x in &loss_parts[1..] {
        loss = b.add(loss, x, HOST);
    }

    ReplicaOut { loss, ntok, grads: grads.into_grads() }
}
