//! Analytic time model: FLOPs/bytes -> seconds on the modeled hardware.
//!
//! Calibration philosophy (EXPERIMENTS.md §Calibration): a single set of
//! constants is fitted so the *single-GPU baseline* throughput lands near
//! the paper's Table 3 (≈2800-3000 src-tok/s for the 142M model at
//! batch 64 on a V100). Every other number in Table 3 — the 1.6× data-
//! parallel, 2.3× model-parallel, 3.4× HybridNMTIF, 4.1× HybridNMT
//! scaling factors — then *emerges from the schedule structure*; there
//! are no per-strategy constants.

use crate::config::HwConfig;
use crate::model_spec::OpCost;
use crate::parallel::plan::ReduceAlgo;

/// Kernel execution time: roofline (compute vs memory bound) + launch
/// overhead. The launch overhead term is what punishes per-timestep
/// kernels at small batch — the same effect that makes RNN frameworks
/// slow per-step on real GPUs.
pub fn compute_time(c: &OpCost, hw: &HwConfig) -> f64 {
    let eff = hw.gemm_efficiency * saturation(c.batch, hw.gemm_sat_batch);
    let flops_t = c.flops / (hw.gemm_tflops * 1e12 * eff);
    let mem_t = c.bytes / (hw.mem_bw_gbps * 1e9);
    flops_t.max(mem_t) + hw.launch_overhead_us * 1e-6
}

/// Batch-utilization curve: b/(b + half). Ops with batch 0 are treated
/// as batch-insensitive (elementwise / host work at full efficiency).
pub fn saturation(batch: usize, half: f64) -> f64 {
    if batch == 0 {
        return 1.0;
    }
    batch as f64 / (batch as f64 + half)
}

/// Point-to-point activation transfer over NVLink.
pub fn transfer_time(bytes: f64, hw: &HwConfig) -> f64 {
    hw.nvlink_latency_us * 1e-6 + bytes / (hw.nvlink_gbps * 1e9)
}

/// Synchronous all-reduce of `bytes` across `k` devices.
pub fn allreduce_time(
    bytes: f64,
    k: usize,
    n_arrays: usize,
    algo: ReduceAlgo,
    hw: &HwConfig,
) -> f64 {
    if k <= 1 {
        return 0.0;
    }
    let kf = k as f64;
    match algo {
        // Bandwidth-optimal ring: 2(k-1)/k of the payload crosses each
        // link, 2(k-1) latency hops.
        ReduceAlgo::Ring => {
            2.0 * (kf - 1.0) / kf * bytes / (hw.nvlink_gbps * 1e9)
                + 2.0 * (kf - 1.0) * hw.nvlink_latency_us * 1e-6
                + n_arrays as f64 * hw.nvlink_latency_us * 1e-6
        }
        // The kvstore path the paper's data-parallel baseline measures:
        // every replica pushes its full gradient to host over PCIe
        // (serialized at the host root), the host reduces, then pushes
        // the updated values back; framework bookkeeping costs a fixed
        // latency per parameter array.
        ReduceAlgo::HostStaged => {
            kf * bytes / (hw.pcie_gbps * 1e9)              // push (serialized at root)
                + kf * bytes / (hw.host_reduce_gbps * 1e9) // host-side reduce
                + kf * bytes / (hw.pcie_gbps * 1e9)        // broadcast back
                + n_arrays as f64 * kf * hw.per_array_latency_us * 1e-6
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwConfig {
        HwConfig::default()
    }

    #[test]
    fn compute_time_has_launch_floor() {
        let t = compute_time(&OpCost::ZERO, &hw());
        assert!((t - hw().launch_overhead_us * 1e-6).abs() < 1e-12);
    }

    #[test]
    fn big_gemm_is_compute_bound() {
        // 1 TFLOP, tiny bytes -> time ≈ flops / effective rate.
        let c = OpCost { flops: 1e12, bytes: 1e3, batch: 0 };
        let h = hw();
        let t = compute_time(&c, &h);
        let expect = 1e12 / (h.gemm_tflops * 1e12 * h.gemm_efficiency);
        assert!((t - expect).abs() / expect < 0.01);
    }

    #[test]
    fn small_op_is_memory_bound() {
        let c = OpCost { flops: 1e3, bytes: 1e9, batch: 0 };
        let h = hw();
        let t = compute_time(&c, &h);
        assert!((t - 1e9 / (h.mem_bw_gbps * 1e9) - h.launch_overhead_us * 1e-6).abs() < 1e-9);
    }

    #[test]
    fn ring_beats_host_staged_for_large_payloads() {
        let h = hw();
        let bytes = 500e6; // ~ the 142M-param full gradient
        let ring = allreduce_time(bytes, 4, 30, ReduceAlgo::Ring, &h);
        let staged = allreduce_time(bytes, 4, 30, ReduceAlgo::HostStaged, &h);
        assert!(staged > 5.0 * ring, "ring {ring} staged {staged}");
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        let h = hw();
        let a = allreduce_time(1e6, 4, 1, ReduceAlgo::Ring, &h);
        let b = allreduce_time(1e8, 4, 1, ReduceAlgo::Ring, &h);
        assert!(b > 10.0 * a);
    }

    #[test]
    fn single_device_allreduce_is_free() {
        assert_eq!(allreduce_time(1e9, 1, 10, ReduceAlgo::Ring, &hw()), 0.0);
    }
}
