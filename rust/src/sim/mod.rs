//! Discrete-event simulator of a multi-GPU node.
//!
//! The substitution that makes this reproduction possible without
//! 4×V100+NVLink (DESIGN.md §2): a plan's *timing* is computed by
//! scheduling its task graph onto modeled devices and links, while its
//! *numerics* run on the CPU PJRT client. The paper's claims are
//! schedule properties (what overlaps, what serializes, what
//! synchronizes), which the simulated makespan preserves.
//!
//! Scheduling model — event-driven list scheduling with backfill:
//! * one compute queue per device; an idle device runs the *ready* task
//!   with the smallest plan id assigned to it. Emission order is thus a
//!   priority, not a hard FIFO: when the critical chain stalls on a
//!   dependency, later-emitted independent work (e.g. the deferred
//!   output-projection steps) backfills the gap — the "side stream"
//!   effect real frameworks get from multiple CUDA streams, without
//!   ever letting one device run two kernels at once;
//! * transfers occupy only the directed link `(from, to)` — DMA
//!   overlaps compute, which is what lets the wavefront's green arrows
//!   pipeline;
//! * all-reduce is a synchronous collective: it starts when it is the
//!   oldest ready task on *every* participating device and all of them
//!   are idle, then blocks them all (priority-ordered, so two
//!   collectives can never deadlock);
//! * host bookkeeping ops are free and unserialised.

pub mod cost;

use crate::config::HwConfig;
use crate::parallel::plan::{Op, Plan, HOST};
use std::collections::{BinaryHeap, BTreeSet, HashMap};

/// One scheduled step (trace export for §Perf inspection).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub step: usize,
    pub device: usize,
    pub start: f64,
    pub end: f64,
    pub kind: &'static str,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end time of one training step (seconds).
    pub makespan: f64,
    /// Busy seconds per device.
    pub device_busy: Vec<f64>,
    /// Seconds spent inside all-reduce collectives (devices blocked).
    pub sync_time: f64,
    /// Seconds of link occupancy (point-to-point transfers).
    pub transfer_time: f64,
    pub events: usize,
}

impl SimResult {
    /// Average compute utilization across devices.
    pub fn utilization(&self) -> f64 {
        if self.makespan == 0.0 {
            return 0.0;
        }
        self.device_busy.iter().sum::<f64>() / (self.device_busy.len() as f64 * self.makespan)
    }
}

/// Which resource a step occupies.
#[derive(Debug, Clone, PartialEq)]
enum Res {
    Dev(usize),
    Link(usize, usize),
    AllDev(Vec<usize>),
    Free,
}

fn resource_of(op: &Op, device: usize) -> Res {
    match op {
        Op::Exec { .. } | Op::Add if device != HOST => Res::Dev(device),
        Op::Transfer { from, .. } => Res::Link(*from, device),
        Op::AllReduce { devices, .. } => Res::AllDev(devices.clone()),
        _ => Res::Free,
    }
}

#[derive(PartialEq)]
struct Ev(f64, usize); // (finish time, step id)

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse for a min-heap on (time, id).
        other
            .0
            .total_cmp(&self.0)
            .then_with(|| other.1.cmp(&self.1))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate one plan execution.
pub fn simulate(plan: &Plan, hw: &HwConfig) -> SimResult {
    simulate_traced(plan, hw, false).0
}

pub fn simulate_traced(plan: &Plan, hw: &HwConfig, trace: bool) -> (SimResult, Vec<TraceEvent>) {
    let n = plan.steps.len();
    let res: Vec<Res> = plan
        .steps
        .iter()
        .map(|s| resource_of(&s.op, s.device))
        .collect();

    // Dependency bookkeeping (deps may repeat a producer: dedup).
    let mut dep_count = vec![0usize; n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, step) in plan.steps.iter().enumerate() {
        let mut ds = step.deps.clone();
        ds.sort_unstable();
        ds.dedup();
        dep_count[i] = ds.len();
        for d in ds {
            dependents[d].push(i);
        }
    }

    let mut ready_dev: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); hw.gpus];
    let mut ready_link: HashMap<(usize, usize), BTreeSet<usize>> = HashMap::new();
    let mut dev_idle = vec![true; hw.gpus];
    let mut link_idle: HashMap<(usize, usize), bool> = HashMap::new();
    let mut events: BinaryHeap<Ev> = BinaryHeap::new();
    let mut finish = vec![0.0f64; n];
    let mut done = vec![false; n];
    let mut dev_busy = vec![0.0f64; hw.gpus];
    let mut sync_time = 0.0;
    let mut transfer_time = 0.0;
    let mut makespan = 0.0f64;
    let mut trace_out = Vec::new();
    let mut n_done = 0usize;

    // Completion cascade: free ops finish instantly, possibly unlocking
    // further free ops at the same timestamp.
    let mut worklist: Vec<usize> = Vec::new();

    macro_rules! complete {
        ($i:expr, $t:expr) => {{
            finish[$i] = $t;
            done[$i] = true;
            n_done += 1;
            makespan = makespan.max($t);
            for &j in &dependents[$i] {
                dep_count[j] -= 1;
                if dep_count[j] == 0 {
                    worklist.push(j);
                }
            }
        }};
    }

    // Seed: steps with no deps.
    for i in 0..n {
        if dep_count[i] == 0 {
            worklist.push(i);
        }
    }

    let mut now = 0.0f64;
    loop {
        // Drain the ready worklist: free ops complete instantly,
        // resource-bound ops enter their queue.
        while let Some(i) = worklist.pop() {
            match &res[i] {
                Res::Free => complete!(i, now),
                Res::Dev(d) => {
                    ready_dev[*d].insert(i);
                }
                Res::Link(a, b) => {
                    ready_link.entry((*a, *b)).or_default().insert(i);
                    link_idle.entry((*a, *b)).or_insert(true);
                }
                Res::AllDev(devs) => {
                    for &d in devs {
                        ready_dev[d].insert(i);
                    }
                }
            }
        }

        // Scheduling pass: start whatever can start at `now`.
        let mut progressed = true;
        while progressed {
            progressed = false;
            for d in 0..hw.gpus {
                if !dev_idle[d] {
                    continue;
                }
                let Some(&i) = ready_dev[d].first() else { continue };
                match &res[i] {
                    Res::Dev(_) => {
                        let dur = cost::compute_time(&plan.steps[i].cost, hw);
                        ready_dev[d].remove(&i);
                        dev_idle[d] = false;
                        dev_busy[d] += dur;
                        events.push(Ev(now + dur, i));
                        if trace {
                            trace_out.push(TraceEvent {
                                step: i,
                                device: d,
                                start: now,
                                end: now + dur,
                                kind: if matches!(plan.steps[i].op, Op::Add) { "add" } else { "exec" },
                            });
                        }
                        progressed = true;
                    }
                    Res::AllDev(devs) => {
                        // Collective: needs every member idle with this
                        // step as its oldest ready task.
                        let can = devs
                            .iter()
                            .all(|&m| dev_idle[m] && ready_dev[m].first() == Some(&i));
                        if can {
                            let (bytes, n_arrays, algo) = match &plan.steps[i].op {
                                Op::AllReduce { bytes, n_arrays, algo, .. } => {
                                    (*bytes, *n_arrays, *algo)
                                }
                                _ => unreachable!(),
                            };
                            let dur = cost::allreduce_time(bytes, devs.len(), n_arrays, algo, hw);
                            for &m in devs {
                                ready_dev[m].remove(&i);
                                dev_idle[m] = false;
                                dev_busy[m] += dur;
                            }
                            sync_time += dur;
                            events.push(Ev(now + dur, i));
                            if trace {
                                trace_out.push(TraceEvent {
                                    step: i,
                                    device: devs[0],
                                    start: now,
                                    end: now + dur,
                                    kind: "allreduce",
                                });
                            }
                            progressed = true;
                        }
                        // If not startable, this device *waits* (strict
                        // priority — prevents collective starvation).
                    }
                    _ => unreachable!(),
                }
            }
            let links: Vec<(usize, usize)> = ready_link
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .map(|(k, _)| *k)
                .collect();
            for key in links {
                if !*link_idle.get(&key).unwrap_or(&true) {
                    continue;
                }
                let q = ready_link.get_mut(&key).unwrap();
                let Some(&i) = q.first() else { continue };
                q.remove(&i);
                let bytes = match &plan.steps[i].op {
                    Op::Transfer { bytes, .. } => *bytes,
                    _ => unreachable!(),
                };
                let dur = cost::transfer_time(bytes, hw);
                link_idle.insert(key, false);
                transfer_time += dur;
                events.push(Ev(now + dur, i));
                if trace {
                    trace_out.push(TraceEvent {
                        step: i,
                        device: plan.steps[i].device,
                        start: now,
                        end: now + dur,
                        kind: "xfer",
                    });
                }
                progressed = true;
            }
        }

        if !worklist.is_empty() {
            continue; // a scheduling start never produces new ready work,
                      // but keep the invariant obvious
        }
        let Some(Ev(t, i)) = events.pop() else { break };
        now = t;
        // Free the resource.
        match &res[i] {
            Res::Dev(d) => dev_idle[*d] = true,
            Res::Link(a, b) => {
                link_idle.insert((*a, *b), true);
            }
            Res::AllDev(devs) => {
                for &m in devs {
                    dev_idle[m] = true;
                }
            }
            Res::Free => {}
        }
        complete!(i, now);
        // Drain same-timestamp completions before rescheduling.
        while let Some(&Ev(t2, _)) = events.peek() {
            if t2 > now {
                break;
            }
            let Ev(_, j) = events.pop().unwrap();
            match &res[j] {
                Res::Dev(d) => dev_idle[*d] = true,
                Res::Link(a, b) => {
                    link_idle.insert((*a, *b), true);
                }
                Res::AllDev(devs) => {
                    for &m in devs {
                        dev_idle[m] = true;
                    }
                }
                Res::Free => {}
            }
            complete!(j, now);
        }
    }

    debug_assert_eq!(n_done, n, "deadlock: {} of {n} steps completed", n_done);

    (
        SimResult {
            makespan,
            device_busy: dev_busy,
            sync_time,
            transfer_time,
            events: n,
        },
        trace_out,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_spec::OpCost;
    use crate::parallel::plan::{PlanBuilder, ReduceAlgo};

    fn hw() -> HwConfig {
        HwConfig::default()
    }

    fn big() -> OpCost {
        OpCost { flops: 1e12, bytes: 1e6, batch: 0 }
    }

    /// Two independent chains on different devices must overlap.
    #[test]
    fn independent_devices_overlap() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        let mut serial = PlanBuilder::new();
        let ps = serial.param("w", 1);
        for dev in [0, 1] {
            b.exec("a".into(), dev, &[p], &[1], big());
            serial.exec("a".into(), 0, &[ps], &[1], big());
        }
        let plan = b.finish(Default::default(), p, p);
        let plan_serial = serial.finish(Default::default(), ps, ps);
        let r = simulate(&plan, &hw());
        let rs = simulate(&plan_serial, &hw());
        assert!(r.makespan < 0.6 * rs.makespan);
    }

    /// A dependency chain across devices serializes (plus transfer).
    #[test]
    fn chain_serializes() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        let x = b.exec("a".into(), 0, &[p], &[1000], big())[0];
        b.exec("bb".into(), 1, &[x], &[1], big());
        let plan = b.finish(Default::default(), p, p);
        let r = simulate(&plan, &hw());
        let one = cost::compute_time(&big(), &hw());
        assert!(r.makespan >= 2.0 * one);
        assert!(r.transfer_time > 0.0);
    }

    /// Later-emitted independent work backfills a dependency stall.
    #[test]
    fn backfill_fills_idle_gaps() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        // Critical chain: dev1 -> dev0 (dev0 idle while dev1 works).
        let x = b.exec("a".into(), 1, &[p], &[1], big())[0];
        b.exec("chain".into(), 0, &[x], &[1], big());
        // Independent later-emitted work for dev0: should run during the
        // stall, adding ~nothing to the makespan.
        b.exec("backfill".into(), 0, &[p], &[1], big());
        let plan = b.finish(Default::default(), p, p);
        let r = simulate(&plan, &hw());
        let one = cost::compute_time(&big(), &hw());
        assert!(
            r.makespan < 2.2 * one,
            "backfill failed: {} vs {}",
            r.makespan,
            2.0 * one
        );
    }

    /// Earlier-emitted tasks win ties (priority = emission order).
    #[test]
    fn priority_prefers_earlier_steps() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        let first = b.exec("first".into(), 0, &[p], &[1], big())[0];
        b.exec("second".into(), 0, &[p], &[1], big());
        let plan = b.finish(Default::default(), first, first);
        let (_, tr) = simulate_traced(&plan, &hw(), true);
        assert!(tr[0].step < tr[1].step);
        assert!(tr[0].start < tr[1].start);
    }

    /// All-reduce blocks all participants until done.
    #[test]
    fn allreduce_blocks_devices() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        let g0 = b.exec("a".into(), 0, &[p], &[1000], big())[0];
        let g1 = b.exec("a".into(), 1, &[p], &[1000], big())[0];
        let red = b.allreduce(&[g0, g1], vec![0, 1], ReduceAlgo::Ring);
        b.exec("post".into(), 0, &[red], &[1], big());
        let plan = b.finish(Default::default(), p, p);
        let r = simulate(&plan, &hw());
        assert!(r.sync_time > 0.0);
        let one = cost::compute_time(&big(), &hw());
        assert!(r.makespan > 2.0 * one); // compute, sync, compute
    }

    /// Two independent collectives on the same devices run in priority
    /// order without deadlocking.
    #[test]
    fn sequential_collectives_no_deadlock() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        let a0 = b.exec("a".into(), 0, &[p], &[1000], big())[0];
        let a1 = b.exec("a".into(), 1, &[p], &[1000], big())[0];
        let r1 = b.allreduce(&[a0, a1], vec![0, 1], ReduceAlgo::Ring);
        let r2 = b.allreduce(&[a0, a1], vec![0, 1], ReduceAlgo::HostStaged);
        let out = b.add(r1, r2, 0);
        let plan = b.finish(Default::default(), out, out);
        let r = simulate(&plan, &hw());
        assert!(r.sync_time > 0.0);
        assert!(r.makespan.is_finite());
    }

    /// Transfers overlap with unrelated compute (DMA model).
    #[test]
    fn transfer_overlaps_compute() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 1);
        let x = b.exec("a".into(), 0, &[p], &[1_000_000], big())[0];
        b.exec("c".into(), 1, &[x], &[1], big());
        b.exec("d".into(), 0, &[p], &[1], big());
        b.exec("e".into(), 0, &[p], &[1], big());
        let plan = b.finish(Default::default(), p, p);
        let r = simulate(&plan, &hw());
        let one = cost::compute_time(&big(), &hw());
        assert!(r.makespan < 3.2 * one + cost::transfer_time(4e6, &hw()));
    }

    #[test]
    fn host_steps_are_free() {
        let mut b = PlanBuilder::new();
        let p = b.param("w", 16);
        let z = b.zeros(&[4]);
        let s = b.push(Op::SumAll, HOST, &[z], &[1], OpCost::ZERO)[0];
        let plan = b.finish(Default::default(), s, p);
        let r = simulate(&plan, &hw());
        assert_eq!(r.makespan, 0.0);
    }
}
