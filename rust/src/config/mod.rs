//! Configuration system: model dimensions, parallelization strategy,
//! simulated-hardware description, and training hyperparameters.
//!
//! Experiments are fully described by JSON files in `configs/` (see
//! `configs/paper.json` for the paper's Table 2 settings) plus CLI
//! overrides. The artifact manifest written by `python/compile/aot.py`
//! carries the same `ModelDims`, so the two sides can never drift.
//! (Serialization is hand-rolled on `util::json` — the build is fully
//! offline, so there is no serde.)

use crate::util::json::{num, obj, s, Json};
use anyhow::{anyhow, Context, Result};

/// Static model dimensions — one artifact set.
///
/// Mirrors `python/compile/model.py::ModelConfig`; for `real` execution
/// it is *read from the manifest*, for `sim-only` (paper-scale) runs it
/// comes from JSON config.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelDims {
    pub name: String,
    /// Word embedding size (paper Table 2: 512).
    pub d: usize,
    /// LSTM hidden state size (paper: 1024).
    pub h: usize,
    /// Encoder/decoder depth (paper: 4).
    pub layers: usize,
    /// Joint BPE vocabulary (paper: 32K).
    pub vocab: usize,
    /// Full mini-batch B.
    pub batch: usize,
    /// Simulated GPU count G (paper: 4).
    pub gpus: usize,
    /// Per-device batch shard Bs = B / G.
    pub shard: usize,
    /// Padded source length M for the attention block.
    pub max_src: usize,
    /// Padded target length N.
    pub max_tgt: usize,
    /// Decode batch (= widest beam).
    pub beam: usize,
}

impl ModelDims {
    /// The paper's Table 2 model at WMT scale (sim-only: no artifacts).
    pub fn paper() -> Self {
        ModelDims {
            name: "paper".into(),
            d: 512,
            h: 1024,
            layers: 4,
            vocab: 32000,
            batch: 224,
            gpus: 4,
            shard: 56,
            max_src: 25,
            max_tgt: 25,
            beam: 18,
        }
    }

    /// Rescale the batch (per Table 3 row: 64 / 224 / 256), keeping
    /// `shard = batch / gpus` consistent.
    pub fn with_batch(&self, batch: usize) -> Self {
        let mut d = self.clone();
        assert!(batch % self.gpus == 0, "batch {batch} % gpus {}", self.gpus);
        d.batch = batch;
        d.shard = batch / self.gpus;
        d
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelDims {
            name: j.req_str("name")?.to_string(),
            d: j.req_usize("d")?,
            h: j.req_usize("h")?,
            layers: j.req_usize("layers")?,
            vocab: j.req_usize("vocab")?,
            batch: j.req_usize("batch")?,
            gpus: j.req_usize("gpus")?,
            shard: j.req_usize("shard")?,
            max_src: j.req_usize("max_src")?,
            max_tgt: j.req_usize("max_tgt")?,
            beam: j.req_usize("beam")?,
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("d", num(self.d as f64)),
            ("h", num(self.h as f64)),
            ("layers", num(self.layers as f64)),
            ("vocab", num(self.vocab as f64)),
            ("batch", num(self.batch as f64)),
            ("gpus", num(self.gpus as f64)),
            ("shard", num(self.shard as f64)),
            ("max_src", num(self.max_src as f64)),
            ("max_tgt", num(self.max_tgt as f64)),
            ("beam", num(self.beam as f64)),
        ])
    }
}

/// The five parallelization strategies of Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Baseline model (input-feeding) on one device.
    Single,
    /// Baseline replicated on G devices, batch sharded, full-gradient sync.
    Data,
    /// Baseline layers spread over devices (paper Fig. 2), wavefront
    /// encoder, input-feeding-serialized decoder.
    Model,
    /// The paper's contribution (Fig. 3): model-parallel wavefront for the
    /// encoder-decoder, data-parallel attention-softmax, no input-feeding.
    Hybrid,
    /// Ablation: hybrid placement but input-feeding kept (HybridNMTIF).
    HybridIf,
}

impl Strategy {
    pub const ALL: [Strategy; 5] = [
        Strategy::Single,
        Strategy::Data,
        Strategy::Model,
        Strategy::Hybrid,
        Strategy::HybridIf,
    ];

    pub fn uses_input_feeding(self) -> bool {
        !matches!(self, Strategy::Hybrid)
    }

    pub fn label(self) -> &'static str {
        match self {
            Strategy::Single => "baseline (1GPU)",
            Strategy::Data => "w/ data parallelism",
            Strategy::Model => "w/ model parallelism",
            Strategy::Hybrid => "HybridNMT",
            Strategy::HybridIf => "HybridNMTIF",
        }
    }

    pub fn key(self) -> &'static str {
        match self {
            Strategy::Single => "single",
            Strategy::Data => "data",
            Strategy::Model => "model",
            Strategy::Hybrid => "hybrid",
            Strategy::HybridIf => "hybrid_if",
        }
    }

    /// Paper Table 3 mini-batch per strategy: 64 (1 GPU), 256 (DP),
    /// 224 (MP / hybrid) — "determined by the available GPU memories".
    pub fn paper_batch(self) -> usize {
        match self {
            Strategy::Single => 64,
            Strategy::Data => 256,
            _ => 224,
        }
    }
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;
    fn from_str(txt: &str) -> Result<Self> {
        match txt {
            "single" | "baseline" => Ok(Strategy::Single),
            "data" => Ok(Strategy::Data),
            "model" => Ok(Strategy::Model),
            "hybrid" => Ok(Strategy::Hybrid),
            "hybrid_if" | "hybridif" => Ok(Strategy::HybridIf),
            _ => Err(anyhow!("unknown strategy `{txt}` (single|data|model|hybrid|hybrid_if)")),
        }
    }
}

/// Simulated hardware: a 4×V100 NVLink node by default.
///
/// These constants are *calibrated once* (EXPERIMENTS.md §Calibration) so
/// the single-GPU baseline lands near the paper's ~2800-3000 src-tok/s;
/// the relative scaling factors then emerge from the schedules, not from
/// per-strategy fudge factors.
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    pub gpus: usize,
    /// Peak fp32 GEMM throughput per device (TFLOP/s). V100: 15.7.
    pub gemm_tflops: f64,
    /// Asymptotic (large-batch) efficiency for RNN-sized GEMMs under a
    /// 2018-era framework's per-step kernels (calibrated).
    pub gemm_efficiency: f64,
    /// Batch at which GEMM efficiency reaches half its asymptote:
    /// eff(b) = gemm_efficiency * b / (b + gemm_sat_batch). Captures the
    /// V100's poor utilization at mini-batch 64 vs 224 (Table 3's
    /// super-linear hybrid scaling).
    pub gemm_sat_batch: f64,
    /// Device HBM bandwidth (GB/s). V100: 900.
    pub mem_bw_gbps: f64,
    /// Fixed per-kernel-launch overhead (µs): dominates small per-cell
    /// kernels exactly as it did the paper's per-timestep LSTM steps.
    pub launch_overhead_us: f64,
    /// NVLink per-direction bandwidth between any device pair (GB/s).
    pub nvlink_gbps: f64,
    /// NVLink transfer latency (µs).
    pub nvlink_latency_us: f64,
    /// Host PCIe bandwidth (GB/s) — the data-parallel kvstore path.
    pub pcie_gbps: f64,
    /// Host-side reduction bandwidth (GB/s).
    pub host_reduce_gbps: f64,
    /// Per-parameter-array synchronization latency (µs): framework
    /// bookkeeping per tensor in the DP sync path.
    pub per_array_latency_us: f64,
    /// If true, full-model data-parallel sync is staged through the host
    /// (the MXNet-kvstore behaviour the paper measured); the hybrid
    /// strategies' small attention all-reduce always rides NVLink rings.
    pub dp_host_staged: bool,
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig {
            gpus: 4,
            gemm_tflops: 15.7,
            gemm_efficiency: 0.42,
            gemm_sat_batch: 110.0,
            mem_bw_gbps: 900.0,
            launch_overhead_us: 9.0,
            nvlink_gbps: 60.0,
            nvlink_latency_us: 5.0,
            pcie_gbps: 9.5,
            host_reduce_gbps: 18.0,
            per_array_latency_us: 160.0,
            dp_host_staged: true,
        }
    }
}

impl HwConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let d = HwConfig::default();
        let f = |key: &str, dv: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dv);
        Ok(HwConfig {
            gpus: j.get("gpus").and_then(Json::as_usize).unwrap_or(d.gpus),
            gemm_tflops: f("gemm_tflops", d.gemm_tflops),
            gemm_efficiency: f("gemm_efficiency", d.gemm_efficiency),
            gemm_sat_batch: f("gemm_sat_batch", d.gemm_sat_batch),
            mem_bw_gbps: f("mem_bw_gbps", d.mem_bw_gbps),
            launch_overhead_us: f("launch_overhead_us", d.launch_overhead_us),
            nvlink_gbps: f("nvlink_gbps", d.nvlink_gbps),
            nvlink_latency_us: f("nvlink_latency_us", d.nvlink_latency_us),
            pcie_gbps: f("pcie_gbps", d.pcie_gbps),
            host_reduce_gbps: f("host_reduce_gbps", d.host_reduce_gbps),
            per_array_latency_us: f("per_array_latency_us", d.per_array_latency_us),
            dp_host_staged: j.get("dp_host_staged").and_then(Json::as_bool).unwrap_or(d.dp_host_staged),
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("gpus", num(self.gpus as f64)),
            ("gemm_tflops", num(self.gemm_tflops)),
            ("gemm_efficiency", num(self.gemm_efficiency)),
            ("gemm_sat_batch", num(self.gemm_sat_batch)),
            ("mem_bw_gbps", num(self.mem_bw_gbps)),
            ("launch_overhead_us", num(self.launch_overhead_us)),
            ("nvlink_gbps", num(self.nvlink_gbps)),
            ("nvlink_latency_us", num(self.nvlink_latency_us)),
            ("pcie_gbps", num(self.pcie_gbps)),
            ("host_reduce_gbps", num(self.host_reduce_gbps)),
            ("per_array_latency_us", num(self.per_array_latency_us)),
            ("dp_host_staged", Json::Bool(self.dp_host_staged)),
        ])
    }
}

/// Training hyperparameters (paper Table 2 + §4.2).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Adam initial learning rate (paper: 1e-3).
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Multiply LR by this when dev perplexity increases (paper: 0.7).
    pub lr_decay: f64,
    /// Check dev perplexity every this many optimizer steps (paper:
    /// 5000 / 20000 batches for WMT14 / WMT17; scaled to corpus size).
    pub decay_interval: usize,
    /// Total optimizer steps for this run.
    pub steps: usize,
    /// Evaluate dev perplexity every this many steps.
    pub eval_interval: usize,
    /// Uniform init half-width.
    pub init_scale: f64,
    /// Global gradient-norm clip (0 disables).
    pub clip_norm: f64,
    /// RNG seed for init + data order.
    pub seed: u64,
    /// Plain SGD instead of Adam (the OpenNMT-lua comparator default).
    pub sgd: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            lr_decay: 0.7,
            decay_interval: 200,
            steps: 400,
            eval_interval: 25,
            init_scale: 0.08,
            clip_norm: 5.0,
            seed: 0,
            sgd: false,
        }
    }
}

impl TrainConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let d = TrainConfig::default();
        let f = |key: &str, dv: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dv);
        let u = |key: &str, dv: usize| j.get(key).and_then(Json::as_usize).unwrap_or(dv);
        Ok(TrainConfig {
            lr: f("lr", d.lr),
            beta1: f("beta1", d.beta1),
            beta2: f("beta2", d.beta2),
            eps: f("eps", d.eps),
            lr_decay: f("lr_decay", d.lr_decay),
            decay_interval: u("decay_interval", d.decay_interval),
            steps: u("steps", d.steps),
            eval_interval: u("eval_interval", d.eval_interval),
            init_scale: f("init_scale", d.init_scale),
            clip_norm: f("clip_norm", d.clip_norm),
            seed: u("seed", d.seed as usize) as u64,
            sgd: j.get("sgd").and_then(Json::as_bool).unwrap_or(d.sgd),
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("lr", num(self.lr)),
            ("beta1", num(self.beta1)),
            ("beta2", num(self.beta2)),
            ("eps", num(self.eps)),
            ("lr_decay", num(self.lr_decay)),
            ("decay_interval", num(self.decay_interval as f64)),
            ("steps", num(self.steps as f64)),
            ("eval_interval", num(self.eval_interval as f64)),
            ("init_scale", num(self.init_scale)),
            ("clip_norm", num(self.clip_norm)),
            ("seed", num(self.seed as f64)),
            ("sgd", Json::Bool(self.sgd)),
        ])
    }
}

/// Synthetic-corpus parameters (the WMT14/17 stand-ins; DESIGN.md §2).
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    /// `wmt14-sim` or `wmt17-sim`.
    pub dataset: String,
    pub train_sentences: usize,
    pub dev_sentences: usize,
    pub test_sentences: usize,
    /// Fraction of synthetic "back-translated" (noisier) pairs — 0 for
    /// wmt14-sim; the 10M/19.1M proportion for wmt17-sim.
    pub backtranslated_frac: f64,
    pub seed: u64,
}

impl DataConfig {
    pub fn wmt14_sim(train: usize) -> Self {
        DataConfig {
            dataset: "wmt14-sim".into(),
            train_sentences: train,
            dev_sentences: 300,
            test_sentences: 300,
            backtranslated_frac: 0.0,
            seed: 14,
        }
    }

    pub fn wmt17_sim(train: usize) -> Self {
        DataConfig {
            dataset: "wmt17-sim".into(),
            train_sentences: train,
            dev_sentences: 300,
            test_sentences: 300,
            backtranslated_frac: 10_000.0 / 19_122.0,
            seed: 17,
        }
    }

    pub fn by_name(name: &str, train: usize) -> Result<Self> {
        match name {
            "wmt14-sim" | "wmt14" => Ok(Self::wmt14_sim(train)),
            "wmt17-sim" | "wmt17" => Ok(Self::wmt17_sim(train)),
            _ => Err(anyhow!("unknown dataset `{name}`")),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let base = Self::by_name(j.req_str("dataset")?, 2000)?;
        Ok(DataConfig {
            train_sentences: j.get("train_sentences").and_then(Json::as_usize).unwrap_or(base.train_sentences),
            dev_sentences: j.get("dev_sentences").and_then(Json::as_usize).unwrap_or(base.dev_sentences),
            test_sentences: j.get("test_sentences").and_then(Json::as_usize).unwrap_or(base.test_sentences),
            backtranslated_frac: j
                .get("backtranslated_frac")
                .and_then(Json::as_f64)
                .unwrap_or(base.backtranslated_frac),
            seed: j.get("seed").and_then(Json::as_usize).map(|x| x as u64).unwrap_or(base.seed),
            dataset: base.dataset,
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", s(&self.dataset)),
            ("train_sentences", num(self.train_sentences as f64)),
            ("dev_sentences", num(self.dev_sentences as f64)),
            ("test_sentences", num(self.test_sentences as f64)),
            ("backtranslated_frac", num(self.backtranslated_frac)),
            ("seed", num(self.seed as f64)),
        ])
    }
}

/// Top-level experiment config (one JSON file in `configs/`).
#[derive(Debug, Clone)]
pub struct Experiment {
    pub model: ModelDims,
    pub strategy: Strategy,
    pub hw: HwConfig,
    pub train: TrainConfig,
    pub data: DataConfig,
    /// Artifact directory for `real` execution.
    pub artifacts_dir: String,
}

impl Experiment {
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let strategy: Strategy = j.req_str("strategy")?.parse()?;
        Ok(Experiment {
            model: ModelDims::from_json(
                j.get("model").ok_or_else(|| anyhow!("missing `model`"))?,
            )?,
            strategy,
            hw: HwConfig::from_json(j.get("hw").unwrap_or(&Json::Null))?,
            train: TrainConfig::from_json(j.get("train").unwrap_or(&Json::Null))?,
            data: DataConfig::from_json(
                j.get("data").ok_or_else(|| anyhow!("missing `data`"))?,
            )?,
            artifacts_dir: j
                .get("artifacts_dir")
                .and_then(Json::as_str)
                .unwrap_or("artifacts")
                .to_string(),
        })
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_json_text(&text).with_context(|| format!("parsing {path}"))
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", self.model.to_json()),
            ("strategy", s(self.strategy.key())),
            ("hw", self.hw.to_json()),
            ("train", self.train.to_json()),
            ("data", self.data.to_json()),
            ("artifacts_dir", s(&self.artifacts_dir)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for st in Strategy::ALL {
            assert_eq!(st.key().parse::<Strategy>().unwrap(), st);
        }
    }

    #[test]
    fn paper_batches_match_table3() {
        assert_eq!(Strategy::Single.paper_batch(), 64);
        assert_eq!(Strategy::Data.paper_batch(), 256);
        assert_eq!(Strategy::Model.paper_batch(), 224);
        assert_eq!(Strategy::Hybrid.paper_batch(), 224);
        assert_eq!(Strategy::HybridIf.paper_batch(), 224);
    }

    #[test]
    fn with_batch_keeps_shard_consistent() {
        let d = ModelDims::paper().with_batch(256);
        assert_eq!(d.shard, 64);
    }

    #[test]
    fn only_hybrid_drops_input_feeding() {
        assert!(!Strategy::Hybrid.uses_input_feeding());
        assert!(Strategy::HybridIf.uses_input_feeding());
        assert!(Strategy::Single.uses_input_feeding());
    }

    #[test]
    fn experiment_json_roundtrip() {
        let e = Experiment {
            model: ModelDims::paper(),
            strategy: Strategy::Hybrid,
            hw: HwConfig::default(),
            train: TrainConfig::default(),
            data: DataConfig::wmt14_sim(1000),
            artifacts_dir: "artifacts".into(),
        };
        let text = e.to_json().to_string();
        let back = Experiment::from_json_text(&text).unwrap();
        assert_eq!(back.model, e.model);
        assert_eq!(back.strategy, e.strategy);
        assert_eq!(back.hw, e.hw);
        assert_eq!(back.train, e.train);
        assert_eq!(back.data, e.data);
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let e = Experiment::from_json_text(
            r#"{"model": {"name":"t","d":8,"h":16,"layers":2,"vocab":32,
                 "batch":8,"gpus":4,"shard":2,"max_src":6,"max_tgt":6,"beam":3},
                "strategy": "hybrid",
                "data": {"dataset": "wmt14-sim"}}"#,
        )
        .unwrap();
        assert_eq!(e.hw, HwConfig::default());
        assert_eq!(e.train.lr, 1e-3);
    }
}
