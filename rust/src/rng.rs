//! Small deterministic RNG (SplitMix64): parameter init, synthetic data,
//! batch shuffling. Self-contained so every run is reproducible from a
//! single seed and the crate carries no RNG dependency.

/// SplitMix64 — tiny, fast, statistically fine for simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [-scale, scale).
    pub fn uniform(&mut self, scale: f32) -> f32 {
        (self.f64() as f32 * 2.0 - 1.0) * scale
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Zipf-ish rank sample over [0, n): P(k) ∝ 1/(k+2) — a cheap heavy
    /// tail matching natural-language token frequency shape.
    pub fn zipf(&mut self, n: usize) -> usize {
        // Inverse-CDF on the harmonic-ish weights via rejection-free trick:
        // draw u, return floor(exp(u * ln(n+1))) - 1 clamped. This gives a
        // log-uniform (Zipf exponent ~1) distribution.
        let u = self.f64();
        let x = ((n as f64 + 1.0).powf(u)) - 1.0;
        (x as usize).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Rng::new(3);
        let n = 1000;
        let mut head = 0;
        for _ in 0..10_000 {
            if r.zipf(n) < 10 {
                head += 1;
            }
        }
        // Log-uniform: P(k < 10) = ln(11)/ln(1001) ≈ 0.35.
        assert!(head > 2500, "head mass {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_respects_scale() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.uniform(0.08);
            assert!(x.abs() <= 0.08);
        }
    }
}
