//! HybridNMT: hybrid data-model parallel training for Seq2Seq RNN MT.
//!
//! A full-system reproduction of Ono, Utiyama & Sumita (2019): a rust
//! coordinator (this crate) schedules a Luong-attention seq2seq LSTM
//! model whose compute is AOT-compiled from JAX/Pallas to HLO artifacts
//! and executed via PJRT. A discrete-event simulator of a 4×V100 NVLink
//! node times the schedules; the five parallelization strategies of the
//! paper's Table 3 are planners over one task-graph IR.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod config;
pub mod data;
pub mod decode;
pub mod dist;
pub mod metrics;
pub mod model_spec;
pub mod parallel;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod storage;
pub mod tensor;
pub mod train;
pub mod util;
pub mod optim;
