//! Experiment drivers: one function per paper table/figure
//! (DESIGN.md §5 experiment index). Each returns the formatted report
//! and writes machine-readable CSV/JSON next to it under `results/`.

use crate::config::{DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig};
use crate::data::synthetic::{Corpus, GenConfig};
use crate::data::Batcher;
use crate::decode::{
    translate_corpus, BeamConfig, DecodeOptions, DecodeStats, Decoder, LengthNorm,
};
use crate::metrics::corpus_bleu;
use crate::model_spec::param_count;
use crate::parallel::build_plan;
use crate::runtime::{quantize_params, Engine, ParamBank};
use crate::serve::ServeStats;
use crate::sim::simulate;
use crate::storage::local::write_file_atomic;
use crate::tensor::half::SlabDtype;
use crate::tensor::Tensor;
use crate::train::Trainer;
use crate::util::json::Json;
use crate::util::per_sec;
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Make the corpus for a data config, sized to the model dims.
pub fn make_corpus(data: &DataConfig, dims: &ModelDims) -> Corpus {
    let gen = GenConfig::for_dims(dims.max_src, data.backtranslated_frac, data.seed);
    Corpus::generate(
        &data.dataset,
        data.train_sentences,
        data.dev_sentences,
        data.test_sentences,
        &gen,
    )
}

/// Encode + bucket the corpus for an experiment. Errors (rather than
/// panicking later) when the corpus cannot fill one training batch.
pub fn make_batcher(exp: &Experiment, corpus: &Corpus) -> Result<Batcher> {
    Batcher::new(
        corpus,
        exp.model.vocab,
        exp.model.batch,
        exp.model.max_src,
        exp.model.max_tgt,
        exp.train.seed,
    )
}

fn write_results(name: &str, content: &str) {
    let _ = std::fs::create_dir_all("results");
    // Atomic temp + rename: a reader (or a crash) never sees a
    // half-written report file.
    let path = std::path::Path::new("results").join(name);
    let _ = write_file_atomic(&path, content.as_bytes());
}

/// Atomically merge `bench` into the flat name→number perf-tracking
/// file at `path` (all `BENCH_*.json` writers go through here, so
/// repeated sweeps accumulate and a kill mid-write can never leave a
/// torn JSON behind).
fn merge_bench_json(path: &str, bench: BTreeMap<String, Json>) {
    let mut all = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    all.extend(bench);
    let _ = write_file_atomic(
        std::path::Path::new(path),
        Json::Obj(all).to_string().as_bytes(),
    );
}

// ---------------------------------------------------------------- Table 1

/// Dataset statistics (paper Table 1), for both synthetic corpora.
pub fn table1(train14: usize, train17: usize, dims: &ModelDims) -> String {
    let mut out = String::new();
    let c14 = make_corpus(&DataConfig::wmt14_sim(train14), dims);
    let c17 = make_corpus(&DataConfig::wmt17_sim(train17), dims);
    writeln!(out, "Table 1. Datasets (synthetic stand-ins for WMT14/WMT17 En-De).").unwrap();
    writeln!(out, "{:<28}{:>12}{:>12}", "", "wmt14-sim", "wmt17-sim").unwrap();
    let bt14 = c14.train.iter().filter(|p| p.backtranslated).count();
    let bt17 = c17.train.iter().filter(|p| p.backtranslated).count();
    writeln!(out, "{:<28}{:>12}{:>12}", "Training (original)", c14.train.len() - bt14, c17.train.len() - bt17).unwrap();
    writeln!(out, "{:<28}{:>12}{:>12}", "Training (back-translated)", bt14, bt17).unwrap();
    writeln!(out, "{:<28}{:>12}{:>12}", "Training (all)", c14.train.len(), c17.train.len()).unwrap();
    writeln!(out, "{:<28}{:>12}{:>12}", "Development", c14.dev.len(), c17.dev.len()).unwrap();
    writeln!(out, "{:<28}{:>12}{:>12}", "Test", c14.test.len(), c17.test.len()).unwrap();
    write_results("table1.txt", &out);
    out
}

// ---------------------------------------------------------------- Table 2

/// Model hyperparameters + the §4.3 parameter-count check.
pub fn table2(exp: &Experiment) -> String {
    let mut out = String::new();
    let d = &exp.model;
    writeln!(out, "Table 2. Model parameters ({}).", d.name).unwrap();
    for (k, v) in [
        ("word embedding size", d.d.to_string()),
        ("RNN cell type", "Stacked-LSTMs".into()),
        ("hidden state size", d.h.to_string()),
        ("encoder/decoder depth", d.layers.to_string()),
        ("attention type", "global (Luong general)".into()),
        ("optimizer", if exp.train.sgd { "SGD".into() } else { "Adam".into() }),
        ("initial learning rate", format!("{}", exp.train.lr)),
        ("learning rate decay", format!("{}", exp.train.lr_decay)),
        ("vocabulary (joint BPE)", d.vocab.to_string()),
        ("mini-batch", d.batch.to_string()),
    ] {
        writeln!(out, "  {k:<24} {v}").unwrap();
    }
    let with_if = param_count(d, true);
    let without = param_count(d, false);
    writeln!(out, "  parameters (baseline, input-feeding): {:.1}M", with_if as f64 / 1e6).unwrap();
    writeln!(out, "  parameters (HybridNMT):               {:.1}M", without as f64 / 1e6).unwrap();
    writeln!(out, "  paper §4.3 reference:                 142M / 138M (Δ = h·4h = {:.1}M)",
        (d.h * 4 * d.h) as f64 / 1e6).unwrap();
    write_results("table2.txt", &out);
    out
}

// ---------------------------------------------------------------- Table 3

/// One Table 3 row.
#[derive(Debug, Clone)]
pub struct SpeedRow {
    pub label: String,
    pub tok_s: [f64; 2],
    pub scaling: [Option<f64>; 2],
    pub batch: usize,
}

/// Training-speed comparison at paper scale (sim-only): the headline
/// table. The two datasets differ in sequence-length profile (WMT14
/// batches are slightly shorter than WMT17's after BPE).
pub fn table3_rows(hw: &HwConfig) -> Vec<SpeedRow> {
    // Padded / average source lengths per dataset (BPE-token scale,
    // matching the paper's ~8% throughput gap between the datasets).
    let datasets = [(23usize, 21.0f64), (25usize, 22.6f64)];
    let mut rows = Vec::new();

    // OpenNMT-lua comparator: same planner, a LuaTorch-flavoured device
    // profile (heavier per-kernel dispatch, slightly leaner optimizer
    // host work). Modeled, not measured — see EXPERIMENTS.md.
    let mut lua_hw = hw.clone();
    lua_hw.launch_overhead_us *= 0.9;
    lua_hw.per_array_latency_us *= 0.85;
    for (impl_label, hwc, strategies) in [
        ("OpenNMT-lua (modeled)", &lua_hw, &[Strategy::Single, Strategy::Data][..]),
        ("Our implementation", hw, &Strategy::ALL[..]),
    ] {
        let mut base: [f64; 2] = [0.0, 0.0];
        for &st in strategies {
            let mut tok_s = [0.0f64; 2];
            for (di, &(pad_len, avg_len)) in datasets.iter().enumerate() {
                let mut dims = ModelDims::paper().with_batch(st.paper_batch());
                dims.max_src = pad_len;
                dims.max_tgt = pad_len;
                let plan = build_plan(&dims, st, hwc.dp_host_staged);
                let sim = simulate(&plan, hwc);
                tok_s[di] = dims.batch as f64 * avg_len / sim.makespan;
            }
            if st == Strategy::Single {
                base = tok_s;
            }
            let scaling = if st == Strategy::Single {
                [None, None]
            } else {
                [Some(tok_s[0] / base[0]), Some(tok_s[1] / base[1])]
            };
            rows.push(SpeedRow {
                label: format!("{impl_label}: {}", st.label()),
                tok_s,
                scaling,
                batch: st.paper_batch(),
            });
        }
    }
    rows
}

pub fn table3(hw: &HwConfig) -> String {
    let rows = table3_rows(hw);
    let mut out = String::new();
    writeln!(out, "Table 3. Training speed and scaling factors (simulated 4xV100 NVLink).").unwrap();
    writeln!(
        out,
        "{:<44} {:>9} {:>9}  {:>7} {:>7}  {:>6}",
        "", "tok/s 14", "tok/s 17", "scale14", "scale17", "batch"
    )
    .unwrap();
    let mut csv = String::from("system,tok_s_wmt14,tok_s_wmt17,scaling_wmt14,scaling_wmt17,batch\n");
    for r in &rows {
        let s = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
        writeln!(
            out,
            "{:<44} {:>9.0} {:>9.0}  {:>7} {:>7}  {:>6}",
            r.label,
            r.tok_s[0],
            r.tok_s[1],
            s(r.scaling[0]),
            s(r.scaling[1]),
            r.batch
        )
        .unwrap();
        writeln!(
            csv,
            "{},{:.0},{:.0},{},{},{}",
            r.label, r.tok_s[0], r.tok_s[1], s(r.scaling[0]), s(r.scaling[1]), r.batch
        )
        .unwrap();
    }
    writeln!(out, "\nPaper reference: DP 1.60-1.71x, MP 2.32-2.51x, HybridNMTIF 3.43-3.57x, HybridNMT 4.13-4.20x.").unwrap();
    write_results("table3.txt", &out);
    write_results("table3.csv", &csv);
    out
}

/// One row of the measured-vs-simulated speed table.
#[derive(Debug, Clone)]
pub struct WallclockRow {
    pub label: String,
    /// Simulated source-token throughput (modeled 4xV100 node).
    pub sim_tok_s: f64,
    /// Measured source-token throughput of the real parallel executor.
    pub wall_tok_s: f64,
    /// Speedups vs the single-GPU baseline row.
    pub sim_scale: Option<f64>,
    pub wall_scale: Option<f64>,
}

/// Table-3-style report with *both* columns: the simulated speedup the
/// plan schedule predicts and the wall-clock speedup the parallel
/// executor actually delivers at artifact scale. `steps` training steps
/// per strategy are timed after one untimed warmup step (artifact
/// compilation + first parameter upload).
pub fn table3_wallclock(engine: &Engine, hw: &HwConfig, steps: usize) -> Result<String> {
    let dims = engine.dims().clone();
    let steps = steps.max(1);
    let mut rows: Vec<WallclockRow> = Vec::new();
    let mut base: Option<(f64, f64)> = None;
    for st in Strategy::ALL {
        let exp = Experiment {
            model: dims.clone(),
            strategy: st,
            hw: hw.clone(),
            train: TrainConfig { steps, ..Default::default() },
            data: DataConfig::wmt14_sim(600),
            artifacts_dir: String::new(),
        };
        let corpus = make_corpus(&exp.data, &exp.model);
        let mut batcher = make_batcher(&exp, &corpus)?;
        let mut trainer = Trainer::new(engine, &exp)?;
        // Warmup: compile artifacts, fill the parameter bank.
        let warm = batcher.next_train();
        trainer.train_step(&warm)?;
        // Pre-generate batches so host-side batch prep (pad + mask)
        // stays outside the timed region — the sim column excludes it,
        // and it's strategy-independent cost that would dilute the
        // measured scaling.
        let batches: Vec<_> = (0..steps).map(|_| batcher.next_train()).collect();
        let tokens: f64 = batches.iter().map(|b| b.tokens()).sum();
        let t0 = std::time::Instant::now();
        for b in &batches {
            trainer.train_step(b)?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let sim_tok_s = tokens / (steps as f64 * trainer.step_sim.makespan);
        let wall_tok_s = tokens / wall;
        let (sim_scale, wall_scale) = match (st, base) {
            (Strategy::Single, _) => {
                base = Some((sim_tok_s, wall_tok_s));
                (None, None)
            }
            (_, Some((bs, bw))) => (Some(sim_tok_s / bs), Some(wall_tok_s / bw)),
            _ => (None, None),
        };
        rows.push(WallclockRow {
            label: st.label().to_string(),
            sim_tok_s,
            wall_tok_s,
            sim_scale,
            wall_scale,
        });
    }

    let mut out = String::new();
    writeln!(
        out,
        "Table 3b. Simulated vs measured wall-clock speed (artifact set `{}`, {} timed steps/strategy).",
        dims.name, steps
    )
    .unwrap();
    writeln!(
        out,
        "{:<24} {:>11} {:>11}  {:>7} {:>7}",
        "", "sim tok/s", "wall tok/s", "sim x", "wall x"
    )
    .unwrap();
    let mut csv = String::from("system,sim_tok_s,wall_tok_s,sim_scale,wall_scale\n");
    let s = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
    for r in &rows {
        writeln!(
            out,
            "{:<24} {:>11.0} {:>11.1}  {:>7} {:>7}",
            r.label,
            r.sim_tok_s,
            r.wall_tok_s,
            s(r.sim_scale),
            s(r.wall_scale)
        )
        .unwrap();
        writeln!(
            csv,
            "{},{:.1},{:.2},{},{}",
            r.label,
            r.sim_tok_s,
            r.wall_tok_s,
            s(r.sim_scale),
            s(r.wall_scale)
        )
        .unwrap();
    }
    writeln!(
        out,
        "\nsim = modeled 4xV100 schedule; wall = parallel executor on this host's cores.\n\
         Absolute wall numbers reflect CPU PJRT artifacts; the *scaling* column is the claim."
    )
    .unwrap();
    let st = engine.stats();
    writeln!(
        out,
        "engine: {} executions, {} uploads ({:.1} MB), {} buffer hits ({:.1} MB re-upload avoided)",
        st.executions,
        st.uploads,
        st.upload_bytes as f64 / 1e6,
        st.buffer_hits,
        st.upload_bytes_saved as f64 / 1e6
    )
    .unwrap();
    write_results("table3_wallclock.txt", &out);
    write_results("table3_wallclock.csv", &csv);
    Ok(out)
}

// --------------------------------------------------------------- Figure 4

/// Convergence curves: dev perplexity vs *simulated* wall-clock for all
/// five strategies on one dataset (real training at artifact scale).
pub fn figure4(
    engine: &Engine,
    data: &DataConfig,
    train_cfg: &TrainConfig,
    hw: &HwConfig,
    strategies: &[Strategy],
) -> Result<String> {
    let dims = engine.dims().clone();
    let corpus = make_corpus(data, &dims);
    let mut out = String::new();
    writeln!(out, "Figure 4. Convergence on {} (dev ppl vs simulated hours).", data.dataset).unwrap();
    let mut csv = String::from("strategy,step,sim_hours,dev_ppl,lr\n");
    let mut curves: Vec<(Strategy, Vec<(f64, f64)>)> = Vec::new();

    for &st in strategies {
        let exp = Experiment {
            model: dims.clone(),
            strategy: st,
            hw: hw.clone(),
            train: train_cfg.clone(),
            data: data.clone(),
            artifacts_dir: String::new(),
        };
        let mut batcher = make_batcher(&exp, &corpus)?;
        let mut trainer = Trainer::new(engine, &exp)?;
        trainer.run(&mut batcher, |_| {})?;
        for p in trainer.history() {
            writeln!(csv, "{},{},{:.6},{:.4},{:.6}", st.key(), p.step, p.sim_hours, p.dev_ppl, p.lr).unwrap();
        }
        let curve: Vec<(f64, f64)> =
            trainer.history().iter().map(|p| (p.sim_hours, p.dev_ppl)).collect();
        let final_ppl = curve.last().map(|x| x.1).unwrap_or(f64::NAN);
        writeln!(
            out,
            "  {:<22} final dev-ppl {:>8.2} after {:>8.2} sim-s ({} steps @ {:.1} ms/step)",
            st.label(),
            final_ppl,
            curve.last().map(|x| x.0 * 3600.0).unwrap_or(0.0),
            trainer.steps_done(),
            trainer.step_sim.makespan * 1e3,
        )
        .unwrap();
        curves.push((st, curve));
    }
    out.push_str(&ascii_curves(&curves));
    write_results(&format!("figure4_{}.csv", data.dataset), &csv);
    write_results(&format!("figure4_{}.txt", data.dataset), &out);
    Ok(out)
}

/// Minimal ASCII multi-curve plot (x = sim hours, y = dev ppl, log-ish).
fn ascii_curves(curves: &[(Strategy, Vec<(f64, f64)>)]) -> String {
    let (w, h) = (72usize, 18usize);
    let mut pts: Vec<(f64, f64, char)> = Vec::new();
    for (st, c) in curves {
        let ch = match st {
            Strategy::Single => 'S',
            Strategy::Data => 'D',
            Strategy::Model => 'M',
            Strategy::Hybrid => 'H',
            Strategy::HybridIf => 'I',
        };
        for &(x, y) in c {
            if y.is_finite() {
                pts.push((x, y.ln(), ch));
            }
        }
    }
    if pts.is_empty() {
        return String::new();
    }
    let xmax = pts.iter().map(|p| p.0).fold(0.0, f64::max).max(1e-9);
    let ymin = pts.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let ymax = pts.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max).max(ymin + 1e-9);
    let mut grid = vec![vec![' '; w]; h];
    for (x, y, ch) in pts {
        let xi = ((x / xmax) * (w - 1) as f64) as usize;
        let yi = (((ymax - y) / (ymax - ymin)) * (h - 1) as f64) as usize;
        grid[yi][xi] = ch;
    }
    let mut out = String::new();
    out.push_str(&format!(
        "\n  ln(dev ppl): {ymax:.2} (top) .. {ymin:.2} (bottom); x: 0 .. {:.2} sim-seconds\n",
        xmax * 3600.0
    ));
    out.push_str(
        "  NOTE: single/data/model/hybrid_if share identical math (the integration\n  suite asserts equal gradients), so their per-step ppl coincides and the\n  separation on this plot is purely the simulated time axis -- the paper's point.\n",
    );
    out.push_str("  S=baseline D=data M=model H=HybridNMT I=HybridNMTIF\n");
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(w));
    out.push('\n');
    out
}

// ---------------------------------------------------------------- Table 4

/// BLEU sweep over beam size x normalization (paper Table 4), on the
/// dev set, for a trained model.
pub fn table4(
    engine: &Engine,
    batcher: &Batcher,
    decoder: &Decoder,
    corpus: &Corpus,
    gnmt: bool,
    beams: &[usize],
    norm_values: &[f64],
) -> Result<String> {
    let _ = engine;
    let mut out = String::new();
    let family = if gnmt { "GNMT normalization (OpenNMT-lua comparator)" } else { "Marian length normalization (HybridNMT)" };
    writeln!(out, "Table 4 ({family}), dev BLEU:").unwrap();
    write!(out, "{:<18}", "norm \\ beam").unwrap();
    for b in beams {
        write!(out, "{b:>8}").unwrap();
    }
    writeln!(out).unwrap();
    let mut csv = String::from("norm,beam,bleu\n");

    // Dev examples -> (src ids, reference string). Capped: the sweep is
    // 36 (beam, norm) grid cells; 48 sentences keep the full grid under
    // a few minutes on this single-CPU testbed while preserving the
    // relative BLEU structure the paper's Table 4 shows.
    let refs: Vec<(Vec<i32>, String)> = batcher
        .dev
        .iter()
        .take(48)
        .map(|e| (e.src.clone(), batcher.vocab.decode(&e.tgt)))
        .collect();

    // Wall-clock bookkeeping per beam column (decode speed is part of
    // the serving story, so the sweep reports it alongside BLEU).
    let mut beam_secs = vec![0.0f64; beams.len()];
    let mut beam_sents = vec![0usize; beams.len()];
    for &nv in norm_values {
        let label = if gnmt { format!("({nv:.1}, 0.0)") } else { format!("{nv:.1}") };
        write!(out, "{label:<18}").unwrap();
        for (bi, &beam) in beams.iter().enumerate() {
            let norm = if gnmt {
                LengthNorm::Gnmt { alpha: nv, beta: 0.0 }
            } else {
                LengthNorm::Marian { alpha: nv }
            };
            let cfg = BeamConfig { beam, max_len: decoder.max_len(), norm };
            let mut pairs = Vec::new();
            let t0 = std::time::Instant::now();
            for (src, r) in &refs {
                let hyp = decoder.translate(src, &cfg)?;
                pairs.push((batcher.vocab.decode(&hyp), r.clone()));
            }
            beam_secs[bi] += t0.elapsed().as_secs_f64();
            beam_sents[bi] += refs.len();
            let bleu = corpus_bleu(&pairs);
            write!(out, "{bleu:>8.2}").unwrap();
            writeln!(csv, "{nv},{beam},{bleu:.2}").unwrap();
        }
        writeln!(out).unwrap();
    }
    write!(out, "{:<18}", "sent/s (wall)").unwrap();
    for (bi, _) in beams.iter().enumerate() {
        write!(out, "{:>8.2}", per_sec(beam_sents[bi] as f64, beam_secs[bi])).unwrap();
    }
    writeln!(out).unwrap();
    let _ = corpus;
    write_results(&format!("table4_{}.csv", if gnmt { "gnmt" } else { "marian" }), &csv);
    Ok(out)
}

// ------------------------------------------------------- Decode bench

/// One measured decode configuration (`serve-bench` / `benches/decode`).
#[derive(Debug, Clone)]
pub struct DecodeRow {
    /// `"single"` (reference `Decoder`) or `"batched"`.
    pub engine: String,
    /// Sentences per chunk (1 for the single path).
    pub batch: usize,
    /// Worker replicas (1 for the single path).
    pub devices: usize,
    /// Beam width.
    pub beam: usize,
    /// Weight precision the parameter bank served: `"f32"`, or
    /// `"int8"` for post-training-quantized rows.
    pub quant: String,
    /// Fraction of sentences whose output tokens differ from the f32
    /// single-sentence reference. Always 0 for f32 rows (those are
    /// gated exactly token-identical); int8 rows are gated against the
    /// caller's acceptance threshold.
    pub accept_delta: f64,
    /// Throughput + residency counters of the run.
    pub stats: DecodeStats,
}

/// Sustained-translation benchmark: decode `srcs` with the
/// single-sentence reference decoder and with the batched engine at
/// each `(batch, devices)` combination, and report wall-clock
/// sentences/sec side by side. Writes `results/decode_bench.{txt,csv}`
/// and `BENCH_decode.json` (flat name → number, same convention as the
/// other `BENCH_*.json` perf-tracking files).
///
/// With `int8_gate = Some(max_delta)` the sweep repeats every batched
/// configuration against an int8 post-training-quantized parameter
/// bank, reporting upload bytes and the token-identity delta vs the
/// f32 reference — and errors if any quantized row's delta exceeds
/// `max_delta` (fraction of sentences allowed to differ).
#[allow(clippy::too_many_arguments)]
pub fn decode_bench(
    engine: &Engine,
    params: &BTreeMap<String, Tensor>,
    bank: &ParamBank,
    input_feeding: bool,
    srcs: &[Vec<i32>],
    cfg: &BeamConfig,
    batches: &[usize],
    devices: &[usize],
    int8_gate: Option<f64>,
) -> Result<String> {
    let mut rows: Vec<DecodeRow> = Vec::new();

    // Reference: one sentence at a time through the host path.
    let dec = Decoder::new(engine, params, input_feeding);
    let t0 = std::time::Instant::now();
    let mut out_tokens = 0usize;
    let mut ref_hyps: Vec<Vec<i32>> = Vec::with_capacity(srcs.len());
    for s in srcs {
        let hyp = dec.translate(s, cfg)?;
        out_tokens += hyp.len();
        ref_hyps.push(hyp);
    }
    rows.push(DecodeRow {
        engine: "single".into(),
        batch: 1,
        devices: 1,
        beam: cfg.beam,
        quant: "f32".into(),
        accept_delta: 0.0,
        stats: DecodeStats {
            sentences: srcs.len(),
            out_tokens,
            wall_s: t0.elapsed().as_secs_f64(),
            ..Default::default()
        },
    });

    for &batch in batches {
        for &dv in devices {
            let opts = DecodeOptions { batch, devices: dv };
            let (hyps, stats) =
                translate_corpus(engine, params, bank, input_feeding, srcs, cfg, &opts)?;
            // The bench doubles as a correctness gate: batched output
            // must match the reference token-for-token.
            for (i, (h, r)) in hyps.iter().zip(&ref_hyps).enumerate() {
                if h != r {
                    return Err(anyhow::anyhow!(
                        "batched decode (batch {batch}, devices {dv}) diverged from the \
                         single-sentence reference at sentence {i}"
                    ));
                }
            }
            rows.push(DecodeRow {
                engine: "batched".into(),
                batch,
                devices: dv,
                beam: cfg.beam,
                quant: "f32".into(),
                accept_delta: 0.0,
                stats,
            });
        }
    }

    if let Some(max_delta) = int8_gate {
        // Fresh bank for the quantized rows: a bank never serves mixed
        // precisions, so the f32 sweep's bank is left untouched.
        let qbank = ParamBank::new();
        qbank.set_quantized(std::sync::Arc::new(quantize_params(params)));
        for &batch in batches {
            for &dv in devices {
                let opts = DecodeOptions { batch, devices: dv };
                let (hyps, stats) =
                    translate_corpus(engine, params, &qbank, input_feeding, srcs, cfg, &opts)?;
                let differing = hyps.iter().zip(&ref_hyps).filter(|(h, r)| h != r).count();
                let delta = differing as f64 / srcs.len().max(1) as f64;
                if delta > max_delta {
                    return Err(anyhow::anyhow!(
                        "int8 decode (batch {batch}, devices {dv}): {differing}/{} sentences \
                         diverged from the f32 reference — accept delta {delta:.3} exceeds the \
                         gate {max_delta:.3}",
                        srcs.len()
                    ));
                }
                rows.push(DecodeRow {
                    engine: "batched".into(),
                    batch,
                    devices: dv,
                    beam: cfg.beam,
                    quant: "int8".into(),
                    accept_delta: delta,
                    stats,
                });
            }
        }
    }
    Ok(decode_bench_table(&rows, srcs.len()))
}

/// Render decode-bench rows and persist them (`results/` + the
/// `BENCH_decode.json` perf-tracking file).
pub fn decode_bench_table(rows: &[DecodeRow], sentences: usize) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Decode throughput ({sentences} sentences/config; batched output verified \
         token-identical to the single-sentence reference)."
    )
    .unwrap();
    writeln!(
        out,
        "{:<10} {:>6} {:>8} {:>5} {:>6}  {:>9} {:>9} {:>8}  {:>12} {:>12} {:>9} {:>6}",
        "engine", "batch", "devices", "beam", "quant", "sent/s", "tok/s", "wall s",
        "param up/hit", "state up/hit", "up kB", "Δtok"
    )
    .unwrap();
    let mut csv = String::from(
        "engine,batch,devices,beam,quant,sent_per_s,tok_per_s,wall_s,param_uploads,param_hits,\
         state_uploads,state_hits,bytes_uploaded,accept_delta\n",
    );
    let mut bench: BTreeMap<String, Json> = BTreeMap::new();
    let base = rows.first().map(|r| r.stats.sentences_per_sec());
    for r in rows {
        let st = &r.stats;
        writeln!(
            out,
            "{:<10} {:>6} {:>8} {:>5} {:>6}  {:>9.2} {:>9.1} {:>8.2}  {:>12} {:>12} {:>9.1} {:>6.3}",
            r.engine,
            r.batch,
            r.devices,
            r.beam,
            r.quant,
            st.sentences_per_sec(),
            st.tokens_per_sec(),
            st.wall_s,
            format!("{}/{}", st.param_uploads, st.param_hits),
            format!("{}/{}", st.state_uploads, st.state_hits),
            st.param_bytes_uploaded as f64 / 1e3,
            r.accept_delta,
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{},{},{},{:.3},{:.2},{:.4},{},{},{},{},{},{:.4}",
            r.engine,
            r.batch,
            r.devices,
            r.beam,
            r.quant,
            st.sentences_per_sec(),
            st.tokens_per_sec(),
            st.wall_s,
            st.param_uploads,
            st.param_hits,
            st.state_uploads,
            st.state_hits,
            st.param_bytes_uploaded,
            r.accept_delta,
        )
        .unwrap();
        let key = if r.engine == "single" {
            format!("single.beam{}", r.beam)
        } else if r.quant != "f32" {
            // Quantized rows get their own prefix so f32 keys stay
            // byte-stable across sweeps with and without --quantize.
            format!("{}.batch{}.devices{}.beam{}", r.quant, r.batch, r.devices, r.beam)
        } else {
            format!("batch{}.devices{}.beam{}", r.batch, r.devices, r.beam)
        };
        bench.insert(format!("{key}.sent_per_s"), Json::Num(st.sentences_per_sec()));
        bench.insert(format!("{key}.wall_ns"), Json::Num(st.wall_s * 1e9));
        // Quantization schema (numeric-only values: quant cell is the
        // weight bit-width under quantization, 0 = unquantized f32).
        bench.insert(
            format!("{key}.quant"),
            Json::Num(if r.quant == "int8" { 8.0 } else { 0.0 }),
        );
        bench.insert(
            format!("{key}.bytes_uploaded"),
            Json::Num(st.param_bytes_uploaded as f64),
        );
        bench.insert(format!("{key}.accept_delta"), Json::Num(r.accept_delta));
    }
    if let (Some(base), Some(best)) = (
        base,
        rows.iter()
            .filter(|r| r.engine == "batched" && r.quant == "f32")
            .map(|r| r.stats.sentences_per_sec())
            .max_by(|a, b| a.total_cmp(b)),
    ) {
        writeln!(
            out,
            "\nbest batched config: {:.2}x the single-sentence path",
            best / base.max(1e-9)
        )
        .unwrap();
        // Beam-qualified like every other key, so multi-beam sweeps
        // accumulate instead of overwriting each other's headline.
        let beam = rows.first().map_or(0, |r| r.beam);
        bench.insert(
            format!("beam{beam}.batched_vs_single_speedup"),
            Json::Num(best / base.max(1e-9)),
        );
    }
    // Merge into an existing BENCH_decode.json so sweeps over several
    // beams (benches/decode.rs) accumulate instead of clobbering.
    merge_bench_json("BENCH_decode.json", bench);
    write_results("decode_bench.txt", &out);
    write_results("decode_bench.csv", &csv);
    out
}

// -------------------------------------------------------- Serve bench

/// One measured online-serving configuration (`serve-load`).
#[derive(Debug, Clone)]
pub struct ServeRow {
    /// Decode replicas the scheduler dispatched over.
    pub replicas: usize,
    /// Beam width.
    pub beam: usize,
    /// Offered load of the (identical) arrival schedule, requests/s.
    pub offered_per_s: f64,
    /// Aggregated serving metrics for the run.
    pub stats: ServeStats,
}

/// Render the serving-benchmark table — offered load vs sustained
/// throughput vs tail latency across replica counts — and persist it
/// (`results/serve_bench.{txt,csv}` + the `BENCH_serve.json`
/// perf-tracking file, merged like `BENCH_decode.json` so sweeps
/// accumulate). Every row in one call faced the same deterministic
/// arrival schedule, so the replica column is the only variable.
pub fn serve_table(rows: &[ServeRow]) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Online serving: offered load vs sustained throughput vs tail latency\n\
         (dynamic micro-batching scheduler; identical Poisson arrivals per row;\n\
         response tokens verified identical to the single-sentence reference)."
    )
    .unwrap();
    writeln!(
        out,
        "{:<9} {:>6} {:>9} {:>9} {:>9}  {:>8} {:>8} {:>8}  {:>6} {:>6} {:>6} {:>7}",
        "replicas", "beam", "offered/s", "sent/s", "tok/s", "p50 ms", "p95 ms", "p99 ms",
        "fill", "waste", "depth", "reject"
    )
    .unwrap();
    let mut csv = String::from(
        "replicas,beam,offered_per_s,sent_per_s,tok_per_s,p50_ms,p95_ms,p99_ms,\
         batch_fill,padding_waste,queue_depth_mean,accepted,rejected,invalid,groups,stolen\n",
    );
    let mut bench: BTreeMap<String, Json> = BTreeMap::new();
    for r in rows {
        let st = &r.stats;
        let (p50, p95, p99) = st.latency_percentiles_ms();
        writeln!(
            out,
            "{:<9} {:>6} {:>9.1} {:>9.2} {:>9.1}  {:>8.1} {:>8.1} {:>8.1}  {:>6.2} {:>6.2} {:>6.1} {:>7}",
            r.replicas,
            r.beam,
            r.offered_per_s,
            st.sentences_per_sec(),
            st.tokens_per_sec(),
            p50,
            p95,
            p99,
            st.mean_fill(),
            st.mean_waste(),
            st.mean_depth(),
            st.rejected,
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{:.3},{:.3},{:.2},{:.3},{:.3},{:.3},{:.4},{:.4},{:.2},{},{},{},{},{}",
            r.replicas,
            r.beam,
            r.offered_per_s,
            st.sentences_per_sec(),
            st.tokens_per_sec(),
            p50,
            p95,
            p99,
            st.mean_fill(),
            st.mean_waste(),
            st.mean_depth(),
            st.accepted,
            st.rejected,
            st.invalid,
            st.groups,
            st.stolen_groups,
        )
        .unwrap();
        // Dots would read as nesting in the flat key namespace, so the
        // offered rate is embedded with `p` as the decimal mark.
        let load = format!("{:.1}", r.offered_per_s).replace('.', "p");
        let key = format!("r{}.beam{}.load{load}", r.replicas, r.beam);
        for (suffix, v) in [
            ("p50_ms", p50),
            ("p95_ms", p95),
            ("p99_ms", p99),
            ("sent_per_s", st.sentences_per_sec()),
            ("tok_per_s", st.tokens_per_sec()),
            ("batch_fill", st.mean_fill()),
            ("padding_waste", st.mean_waste()),
            ("queue_depth_mean", st.mean_depth()),
            ("rejected", st.rejected as f64),
            ("invalid", st.invalid as f64),
        ] {
            bench.insert(format!("{key}.{suffix}"), Json::Num(v));
        }
    }
    if let (Some(base), Some(best)) = (
        rows.iter()
            .find(|r| r.replicas == 1)
            .map(|r| r.stats.sentences_per_sec()),
        rows.iter()
            .map(|r| r.stats.sentences_per_sec())
            .max_by(|a, b| a.total_cmp(b)),
    ) {
        writeln!(
            out,
            "\nbest replica scaling: {:.2}x the 1-replica sustained throughput",
            best / base.max(1e-9)
        )
        .unwrap();
    }
    merge_bench_json("BENCH_serve.json", bench);
    write_results("serve_bench.txt", &out);
    write_results("serve_bench.csv", &csv);
    out
}

// ------------------------------------------------- Multi-tenant bench

/// One per-tenant row of a multi-tenant serving run (`serve-load
/// --tenants`).
#[derive(Debug, Clone)]
pub struct TenantRow {
    /// Tenant id (hyphenated, never dotted — it becomes a flat
    /// `BENCH_serve.json` key segment).
    pub tenant: String,
    /// Offered load addressed to this tenant, requests/s.
    pub offered_rps: f64,
    /// Completed responses per second for this tenant.
    pub sustained_rps: f64,
    /// Nearest-rank p50 latency, milliseconds.
    pub p50_ms: f64,
    /// Nearest-rank p99 latency, milliseconds.
    pub p99_ms: f64,
    /// Requests refused over this tenant's admission cap.
    pub shed: u64,
    /// HyperLogLog estimate of distinct submitting users.
    pub distinct_users_est: f64,
    /// p99 of the same tenant's schedule served *alone* (the fairness
    /// baseline); NaN when the solo baseline was not run.
    pub solo_p99_ms: f64,
}

/// Render the multi-tenant serving table — per-tenant offered vs
/// sustained load, tail latency (and its ratio to the tenant's solo
/// baseline, the isolation claim), sheds, and distinct users — and
/// persist it: `results/tenant_bench.{txt,csv}`, per-tenant
/// `mt.{tenant}.*` rows in `BENCH_serve.json`, the full Prometheus
/// exposition dump at `results/metrics.prom`, and the registry's
/// label-aggregated totals as `prom.*` keys in `BENCH_serve.json`.
pub fn tenant_table(rows: &[TenantRow]) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Multi-tenant serving: per-tenant isolation under Zipf-skewed load\n\
         (deficit-round-robin dispatch; per-tenant admission caps; p99/solo is\n\
         the fairness column — how much a tenant's tail stretches when it shares\n\
         the fleet with every other tenant)."
    )
    .unwrap();
    writeln!(
        out,
        "{:<12} {:>10} {:>11} {:>8} {:>8} {:>10} {:>7} {:>10}",
        "tenant", "offered/s", "sustained/s", "p50 ms", "p99 ms", "solo p99", "shed", "users est"
    )
    .unwrap();
    let mut csv = String::from(
        "tenant,offered_rps,sustained_rps,p50_ms,p99_ms,solo_p99_ms,p99_vs_solo,shed,distinct_users_est\n",
    );
    let mut bench: BTreeMap<String, Json> = BTreeMap::new();
    for r in rows {
        let ratio = if r.solo_p99_ms.is_finite() && r.solo_p99_ms > 0.0 {
            r.p99_ms / r.solo_p99_ms
        } else {
            f64::NAN
        };
        let fmt_opt = |x: f64| if x.is_finite() { format!("{x:.1}") } else { "-".into() };
        writeln!(
            out,
            "{:<12} {:>10.1} {:>11.2} {:>8.1} {:>8.1} {:>10} {:>7} {:>10.1}",
            r.tenant,
            r.offered_rps,
            r.sustained_rps,
            r.p50_ms,
            r.p99_ms,
            fmt_opt(r.solo_p99_ms),
            r.shed,
            r.distinct_users_est,
        )
        .unwrap();
        writeln!(
            csv,
            "{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{},{:.1}",
            r.tenant,
            r.offered_rps,
            r.sustained_rps,
            r.p50_ms,
            r.p99_ms,
            r.solo_p99_ms,
            ratio,
            r.shed,
            r.distinct_users_est,
        )
        .unwrap();
        let key = format!("mt.{}", r.tenant);
        for (suffix, v) in [
            ("offered_rps", r.offered_rps),
            ("sustained_rps", r.sustained_rps),
            ("p99_ms", r.p99_ms),
            ("shed", r.shed as f64),
            ("distinct_users_est", r.distinct_users_est),
        ] {
            bench.insert(format!("{key}.{suffix}"), Json::Num(v));
        }
        if ratio.is_finite() {
            bench.insert(format!("{key}.p99_vs_solo"), Json::Num(ratio));
        }
    }
    // Snapshot the process-wide metrics registry alongside: the full
    // Prometheus text dump for scraping/validation, and its
    // label-aggregated totals as flat prom.* keys.
    let registry = crate::metrics::Registry::global();
    write_results("metrics.prom", &registry.render());
    for (name, v) in registry.snapshot_totals() {
        if v.is_finite() {
            bench.insert(format!("prom.{name}"), Json::Num(v));
        }
    }
    merge_bench_json("BENCH_serve.json", bench);
    write_results("tenant_bench.txt", &out);
    write_results("tenant_bench.csv", &csv);
    out
}

// -------------------------------------------------------- Train bench

/// One measured training configuration (`train-bench`).
#[derive(Debug, Clone)]
pub struct TrainBenchRow {
    /// Data-parallel replica workers.
    pub replicas: usize,
    /// Gradient-accumulation micro-steps per replica.
    pub accum: usize,
    /// Flat-slab overlapped engine (`true`) or the map-based PR-4
    /// reference (`false`).
    pub flat: bool,
    /// Timed optimizer steps.
    pub steps: usize,
    /// Rows per global batch (`replicas × accum × artifact batch`).
    pub global_batch: usize,
    /// Mean wall seconds per optimizer step, and its phase breakdown.
    pub step_s: f64,
    /// Mean seconds in the fixed-order gradient tree reduce.
    pub reduce_s: f64,
    /// Share of the reduce that ran while replica compute was still in
    /// flight (always 0 for map rows).
    pub overlap_pct: f64,
    /// Mean seconds in the sharded optimizer apply.
    pub apply_s: f64,
    /// Mean seconds stalled waiting on the batch prefetch thread.
    pub stall_s: f64,
    /// Measured source-token throughput (real src tokens / wall).
    pub src_tok_per_s: f64,
    /// Final training loss per token (sanity column: finite, and
    /// comparable across configs with equal global batch).
    pub loss_per_tok: f64,
    /// Parameter uploads per optimizer step summed over replica banks
    /// (expected ≈ `replicas × n_params`).
    pub uploads_per_step: f64,
    /// f32 buffer allocations per optimizer step (hot-path churn; the
    /// flat engine's headline reduction vs the map reference).
    pub allocs_per_step: f64,
    /// Mean seconds per step the training thread stalled on async
    /// checkpoint work (copy-on-write snapshot capture + non-blocking
    /// hand-off; ~0 is the claim).
    pub ckpt_stall_s: f64,
    /// Background-writer checkpoint bandwidth over the timed window
    /// (serialized bytes / writer seconds).
    pub ckpt_bytes_per_s: f64,
    /// Distributed world size (0 = single-process row). Dist rows are
    /// keyed `r{R}.dist{N}.{mode}` in `BENCH_train.json` and excluded
    /// from the single-process scaling summaries.
    pub dist_world: usize,
    /// Distributed mode key (`ps` | `replicated`); empty when
    /// `dist_world == 0`.
    pub dist_mode: String,
    /// Storage precision of the parameter/gradient slabs for this row
    /// (f32 rows keep the historical row keys; f16/bf16 rows get a
    /// `.f16`/`.bf16` key suffix).
    pub precision: SlabDtype,
    /// Mean gradient bytes shipped per optimizer step at the row's
    /// storage dtype (shards × slab elements × bytes/elem) — the
    /// halved-wire-traffic claim of the 16-bit modes.
    pub bytes_per_step: f64,
    /// Optimizer steps skipped by the dynamic loss scaler (overflow in
    /// the folded gradient); always 0 for f32 rows.
    pub overflow_skips: u64,
    /// Supervised chaos row: the world ran under the elastic
    /// supervisor with scripted rank kills. Keyed with a `.chaos`
    /// suffix and carrying the three recovery columns below.
    pub chaos: bool,
    /// World relaunches the supervisor performed for this row.
    pub restarts: u32,
    /// Wall-clock the failures cost (failed incarnations + restart
    /// backoff), milliseconds.
    pub recovery_ms: f64,
    /// Optimizer steps of progress re-run after restarts (work beyond
    /// the checkpoint each relaunch resumed from).
    pub lost_steps: u64,
}

/// Render the training-throughput sweep — replicas × accumulation vs
/// measured step time, phase breakdown and token throughput — and
/// persist it (`results/train_bench.{txt,csv}` + the
/// `BENCH_train.json` perf-tracking file, merged like the other
/// `BENCH_*.json` so repeated sweeps accumulate).
pub fn train_table(rows: &[TrainBenchRow]) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Training throughput: replica fan-out × gradient accumulation\n\
         (flat = overlapped bucketed-reduce slab engine, map = PR-4 reference;\n\
         per-step wall clock with phase breakdown; ovl% = reduce hidden under compute)."
    )
    .unwrap();
    writeln!(
        out,
        "{:<9} {:>6} {:>10} {:>7} {:>7}  {:>9} {:>9} {:>5} {:>9} {:>9} {:>9}  {:>10} {:>9} {:>9} {:>9} {:>10} {:>9} {:>4} {:>4} {:>8} {:>5}",
        "replicas", "accum", "mode", "steps", "gbatch", "step ms", "reduce ms", "ovl%",
        "apply ms", "stall ms", "ck-st ms", "src tok/s", "loss/tok", "uploads", "allocs",
        "ckpt MB/s", "grad kB", "ovf", "rst", "recov ms", "lost"
    )
    .unwrap();
    let mut csv = String::from(
        "replicas,accum,mode,steps,global_batch,step_ms,reduce_ms,overlap_pct,apply_ms,\
         stall_ms,checkpoint_stall_ms,src_tok_per_s,loss_per_tok,uploads_per_step,\
         allocs_per_step,checkpoint_bytes_per_s,precision,bytes_per_step,overflow_skips,\
         restarts,recovery_ms,lost_steps\n",
    );
    let mut bench: BTreeMap<String, Json> = BTreeMap::new();
    for r in rows {
        // Distributed rows run the flat engine; their mode column names
        // the collective instead (`ps:N` / `repl:N` for N processes).
        let mut mode = if r.dist_world > 0 {
            let short = if r.dist_mode == "replicated" { "repl" } else { r.dist_mode.as_str() };
            format!("{short}:{}", r.dist_world)
        } else if r.flat {
            "flat".to_string()
        } else {
            "map".to_string()
        };
        if r.precision != SlabDtype::F32 {
            mode = format!("{mode}/{}", r.precision);
        }
        if r.chaos {
            mode = format!("{mode}+ch");
        }
        writeln!(
            out,
            "{:<9} {:>6} {:>10} {:>7} {:>7}  {:>9.1} {:>9.1} {:>5.1} {:>9.1} {:>9.1} {:>9.2}  \
             {:>10.1} {:>9.3} {:>9.1} {:>9.0} {:>10.1} {:>9.1} {:>4} {:>4} {:>8.1} {:>5}",
            r.replicas,
            r.accum,
            mode,
            r.steps,
            r.global_batch,
            r.step_s * 1e3,
            r.reduce_s * 1e3,
            r.overlap_pct,
            r.apply_s * 1e3,
            r.stall_s * 1e3,
            r.ckpt_stall_s * 1e3,
            r.src_tok_per_s,
            r.loss_per_tok,
            r.uploads_per_step,
            r.allocs_per_step,
            r.ckpt_bytes_per_s / 1e6,
            r.bytes_per_step / 1e3,
            r.overflow_skips,
            r.restarts,
            r.recovery_ms,
            r.lost_steps,
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{},{},{},{:.3},{:.3},{:.2},{:.3},{:.3},{:.4},{:.2},{:.5},{:.1},{:.1},{:.0},{},{:.0},{},{},{:.1},{}",
            r.replicas,
            r.accum,
            mode,
            r.steps,
            r.global_batch,
            r.step_s * 1e3,
            r.reduce_s * 1e3,
            r.overlap_pct,
            r.apply_s * 1e3,
            r.stall_s * 1e3,
            r.ckpt_stall_s * 1e3,
            r.src_tok_per_s,
            r.loss_per_tok,
            r.uploads_per_step,
            r.allocs_per_step,
            r.ckpt_bytes_per_s,
            r.precision,
            r.bytes_per_step,
            r.overflow_skips,
            r.restarts,
            r.recovery_ms,
            r.lost_steps,
        )
        .unwrap();
        // Flat rows keep the historical prefix; map-reference rows get
        // their own `.map` row prefix; distributed rows are keyed by
        // world size + collective mode. All three are schema-checked.
        let mut key = if r.dist_world > 0 {
            format!("r{}.dist{}.{}", r.replicas, r.dist_world, r.dist_mode)
        } else if r.flat {
            format!("r{}.accum{}", r.replicas, r.accum)
        } else {
            format!("r{}.accum{}.map", r.replicas, r.accum)
        };
        if r.precision != SlabDtype::F32 {
            // f32 rows keep their historical keys; 16-bit rows sit next
            // to them under a dtype suffix so sweeps across precisions
            // accumulate instead of clobbering.
            key = format!("{key}.{}", r.precision);
        }
        if r.chaos {
            // Supervised chaos rows sit next to their clean siblings;
            // the suffix is what opts them into the recovery-column
            // schema check in scripts/verify.sh.
            key = format!("{key}.chaos");
        }
        for (suffix, v) in [
            ("tok_per_s", r.src_tok_per_s),
            ("step_ms", r.step_s * 1e3),
            ("reduce_ms", r.reduce_s * 1e3),
            ("overlap_pct", r.overlap_pct),
            ("apply_ms", r.apply_s * 1e3),
            ("stall_ms", r.stall_s * 1e3),
            ("checkpoint_stall_ms", r.ckpt_stall_s * 1e3),
            ("checkpoint_bytes_per_s", r.ckpt_bytes_per_s),
            ("uploads_per_step", r.uploads_per_step),
            ("allocs_per_step", r.allocs_per_step),
            // Mixed-precision schema (BENCH values are numeric-only, so
            // the precision cell is the dtype code: f32=0 f16=1 bf16=2).
            ("precision", r.precision.code() as f64),
            ("bytes_per_step", r.bytes_per_step),
            ("overflow_skips", r.overflow_skips as f64),
        ] {
            bench.insert(format!("{key}.{suffix}"), Json::Num(v));
        }
        if r.chaos {
            for (suffix, v) in [
                ("restarts", r.restarts as f64),
                ("recovery_ms", r.recovery_ms),
                ("lost_steps", r.lost_steps as f64),
            ] {
                bench.insert(format!("{key}.{suffix}"), Json::Num(v));
            }
        }
    }
    if let (Some(base), Some(best)) = (
        rows.iter()
            .find(|r| {
                r.replicas == 1
                    && r.accum == 1
                    && r.flat
                    && r.dist_world == 0
                    && r.precision == SlabDtype::F32
            })
            .map(|r| r.src_tok_per_s),
        rows.iter()
            .filter(|r| r.dist_world == 0 && r.precision == SlabDtype::F32)
            .map(|r| r.src_tok_per_s)
            .max_by(|a, b| a.total_cmp(b)),
    ) {
        writeln!(
            out,
            "\nbest config: {:.2}x the 1-replica/no-accum token throughput",
            best / base.max(1e-9)
        )
        .unwrap();
    }
    for (r_flat, r_map) in rows.iter().filter(|r| r.flat && r.dist_world == 0).filter_map(|rf| {
        rows.iter()
            .find(|rm| {
                !rm.flat && rm.dist_world == 0 && rm.replicas == rf.replicas && rm.accum == rf.accum
            })
            .map(|rm| (rf, rm))
    }) {
        if r_flat.replicas == rows.iter().map(|r| r.replicas).max().unwrap_or(1) {
            writeln!(
                out,
                "flat vs map at {}x{}: {:.1}% of reduce hidden, allocs {:.0} -> {:.0} per step",
                r_flat.replicas,
                r_flat.accum,
                r_flat.overlap_pct,
                r_map.allocs_per_step,
                r_flat.allocs_per_step
            )
            .unwrap();
        }
    }
    writeln!(
        out,
        "token throughput counts real (non-pad) source tokens; absolute numbers are CPU-PJRT,\n\
         the replica scaling and the reduce/apply/stall shares are the claims (docs/PERF.md)."
    )
    .unwrap();
    merge_bench_json("BENCH_train.json", bench);
    write_results("train_bench.txt", &out);
    write_results("train_bench.csv", &csv);
    out
}

// ---------------------------------------------------------------- Table 5

/// Test BLEU comparison (paper Table 5): our baseline vs HybridNMT on
/// both test sets, with the paper's published rows quoted for context.
/// The fourth column is the measured decode throughput of the batched
/// inference engine on each system's test decode (NaN for quoted rows).
pub fn table5(rows: &[(String, f64, f64, f64)]) -> String {
    let mut out = String::new();
    writeln!(out, "Table 5. Test BLEU.").unwrap();
    writeln!(
        out,
        "{:<36}{:>10}{:>10}{:>12}",
        "System", "wmt14-sim", "wmt17-sim", "dec sent/s"
    )
    .unwrap();
    for (label, b14, b17, sps) in rows {
        let f = |x: f64| if x.is_nan() { "-".to_string() } else { format!("{x:.2}") };
        writeln!(out, "{:<36}{:>10}{:>10}{:>12}", label, f(*b14), f(*b17), f(*sps)).unwrap();
    }
    writeln!(out, "\nPaper reference (real WMT test sets): OpenNMT-lua 21.85/25.92, HybridNMT 22.71/26.91;").unwrap();
    writeln!(out, "the reproduction claim is *parity or better for HybridNMT vs baseline*, not absolute BLEU.").unwrap();
    write_results("table5.txt", &out);
    out
}
