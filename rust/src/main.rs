//! hybridnmt — leader entrypoint / CLI.
//!
//! Subcommands (see README):
//!   train      train one strategy on a synthetic corpus (real numerics)
//!   translate  beam-search decode a checkpoint on the test set
//!   sim        simulate one strategy's step schedule, print breakdown
//!   table1..5  regenerate the paper's tables
//!   figure4    regenerate the convergence-speed figure
//!
//! Flag parsing is hand-rolled (fully-offline build: no clap).

use anyhow::{anyhow, Context, Result};
use hybridnmt::config::{DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig};
use hybridnmt::data::with_prefetch;
use hybridnmt::decode::{translate_corpus, BeamConfig, DecodeOptions, Decoder, LengthNorm};
use hybridnmt::dist::{CommOpts, DistComm, DistMode, TcpTransport};
use hybridnmt::metrics::corpus_bleu;
use hybridnmt::parallel::build_plan;
use hybridnmt::report;
use hybridnmt::runtime::{Engine, ParamBank};
use hybridnmt::serve::{
    drive_arrivals, drive_tenant_arrivals, poisson_arrivals, run_server, run_tenant_server,
    tenant_arrivals, ServeOptions, TenantDriveReport, TenantOpts, TenantRegistry,
};
use hybridnmt::sim::simulate;
use hybridnmt::storage::{local::write_file_atomic, LocalDir, Retrying, RetryPolicy};
use hybridnmt::tensor::half::SlabDtype;
use hybridnmt::train::{checkpoint, init_params, StepMode, Trainer};
use hybridnmt::util::per_sec;
use std::sync::Arc;

struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1).peekable();
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got `{a}`"))?
                .to_string();
            // Boolean flags (--sequential, --real, --sgd, ...) may be
            // followed by another flag: a `--`-prefixed token is never a
            // value, so leave it for the next iteration.
            let val = match it.peek() {
                Some(next) if !next.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            flags.insert(key, val);
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

const HELP: &str = "\
hybridnmt — hybrid data-model parallel Seq2Seq RNN MT (Ono et al., 2019)

USAGE: hybridnmt <command> [--flag value]...

COMMANDS
  train      --strategy S --dataset D [--steps N] [--model tiny|small]
             [--sentences N] [--seed N] [--ckpt out.bin] [--config file.json]
             [--replicas R (data-parallel train-step fan-out)]
             [--accum K (gradient-accumulation micro-steps per replica)]
             [--resume ck.bin | --resume DIR (a checkpoint directory:
             restores the newest durable checkpoint via its `latest`
             pointer)]
             [--ckpt-dir DIR (async fault-tolerant checkpointing: a
             background writer publishes v2 checkpoints to DIR via
             atomic write + `latest` pointer, off the training thread)]
             [--checkpoint-every N (snapshot cadence in steps, default 25)]
             [--sequential (disable the parallel plan executor)]
             [--bucket-kib N (flat-slab bucket size, default 256)]
             [--map-step (PR-4 map-based step engine instead of the
             overlapped flat-slab engine)]
             [--precision f32|f16|bf16 (storage precision of the
             parameter/gradient slabs; 16-bit modes keep an f32 master
             copy in the optimizer and use dynamic loss scaling;
             default f32, bitwise-identical to earlier releases)]
             [--dist N (multi-process data parallelism: spawn N rank
             processes over loopback TCP; params stay bitwise-identical
             to the single-process run)]
             [--dist-mode ps|replicated (rank-0 parameter server vs
             hierarchical tree+ring all-reduce; default ps)]
             [--dist-die R@S (fault drill: rank R hard-exits before step
             S; surviving ranks must fail with a typed step-boundary
             error, never hang)] [--dist-timeout-ms T (peer read/connect
             timeout, default 10000)]
             [--dist-supervise (elastic mode, requires --ckpt-dir: the
             launcher monitors per-rank heartbeats, tears the world down
             on a failure and relaunches a fresh incarnation that
             resumes bitwise-exactly from the newest durable
             checkpoint)] [--max-restarts N (relaunch budget, default 3;
             exhaustion is a typed error, never a hang)]
             [--heartbeat-ms T (beat interval; a rank silent for 4
             beats is declared dead; default dist-timeout-ms / 4)]
  train-bench  [--model tiny] [--steps N] [--replicas R] [--accum K]
             [--strategy S] [--sentences N] [--sequential] [--bucket-kib N]
             [--checkpoint-every N (default 2; async-checkpoint cost is
             part of the sweep: checkpoint_stall_ms ~ 0 is the claim)]
             [--dist N (adds r{R}.dist{N}.{ps,replicated} rows: an
             N-rank in-process world per collective mode)]
             [--chaos (with --dist N: adds r1.dist{N}.{mode}.chaos rows
             — a supervised world with a scripted rank kill, recovered
             from durable checkpoints; gates the recovered params
             bitwise against the fault-free run and reports
             restarts/recovery_ms/lost_steps; also dumps supervisor
             counters to results/metrics_train.prom)]
             [--precision f32,bf16 (comma list; adds 16-bit rows — keyed
             r{R}.accum{K}.{f16,bf16} with bytes_per_step and
             overflow_skips columns — next to the f32 sweep; 16-bit rows
             gate within 10% of the f32 loss)]
             (training-throughput sweep over replicas 1..R x accum {1, K},
             each config on the flat-slab engine AND the map reference;
             writes BENCH_train.json + results/train_bench.{txt,csv})
  translate  --ckpt file.bin [--model small] [--beam B] [--alpha A]
             [--dataset D] [--strategy S (sets input-feeding)]
             [--batch N --devices D (batched multi-device inference engine)]
  serve-bench  [--ckpt file.bin] [--model small] [--beam B] [--batch N]
             [--devices D] [--n sentences] (sustained decode throughput;
             writes BENCH_decode.json + results/decode_bench.{txt,csv})
             [--quantize int8 (adds int8.batch{N}.devices{D} rows: the
             batched sweep against a post-training-quantized bank, with
             bytes_uploaded and the token-identity delta vs the f32
             reference)] [--accept-delta F (gate: max fraction of
             sentences allowed to differ under int8; default 0.15)]
  serve-load [--ckpt file.bin] [--model small] [--beam B] [--replicas R]
             [--rate req/s] [--requests N] [--pool N distinct sentences]
             [--queue CAP] [--max-wait-ms W] [--bucket-width T] [--seed S]
             [--alpha A] [--strategy S (sets input-feeding)]
             (online scheduler under deterministic Poisson arrivals,
             replica sweep 1..R; writes BENCH_serve.json +
             results/serve_bench.{txt,csv})
             [--tenants T (multi-tenant mode: T tenants under Zipf-skewed
             popularity, deficit-round-robin fairness, per-tenant rows in
             BENCH_serve.json + results/tenant_bench.{txt,csv} + the
             Prometheus dump at results/metrics.prom)]
             [--zipf-s S (tenant popularity skew, default 1.0)]
             [--users U (distinct users per tenant, default 200)]
             [--tenant-queue C (per-tenant admission cap, default 64)]
             [--swap-at F (hot-swap the hottest tenant after fraction F
             of the schedule; responses never drop or mix generations)]
             [--fairness-factor F (gate: every tenant's shared-fleet p99
             must stay within F x its solo p99; 0 = report only)]
  sim        --strategy S [--batch B] [--trace out.csv] (schedule breakdown)
  table1     [--sentences14 N] [--sentences17 N]
  table2     [--model tiny|small|paper]
  table3     [--real [--steps N] (adds measured wall-clock columns; needs artifacts)]
  table4     --ckpt file.bin [--model small] [--dataset D] [--gnmt]
  table5     [--steps N] [--model small] (trains baseline+hybrid, decodes both test sets)
  figure4    --dataset D [--steps N] [--model small]

Strategies: single | data | model | hybrid | hybrid_if
Datasets:   wmt14-sim | wmt17-sim
Artifacts:  --artifacts DIR (default ./artifacts); run `make artifacts` first.
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn build_experiment(args: &Args, engine: &Engine) -> Result<Experiment> {
    if let Some(path) = args.get("config") {
        return Experiment::load(path);
    }
    let strategy: Strategy = args.str_or("strategy", "hybrid").parse()?;
    let dims = engine.dims().clone();
    let sentences = args.usize("sentences", 3000)?;
    let mut train = TrainConfig {
        steps: args.usize("steps", 300)?,
        eval_interval: args.usize("eval-interval", 25)?,
        seed: args.usize("seed", 0)? as u64,
        ..Default::default()
    };
    train.decay_interval = args.usize("decay-interval", 100)?;
    if args.get("sgd").is_some() {
        train.sgd = true;
        // OpenNMT-lua's default SGD learning rate.
        train.lr = 1.0;
    }
    if let Some(lr) = args.get("lr") {
        train.lr = lr.parse().with_context(|| format!("--lr {lr}"))?;
    }
    Ok(Experiment {
        model: dims,
        strategy,
        hw: HwConfig::default(),
        train,
        data: DataConfig::by_name(args.str_or("dataset", "wmt14-sim"), sentences)?,
        artifacts_dir: args.str_or("artifacts", "artifacts").to_string(),
    })
}

fn load_engine(args: &Args) -> Result<Engine> {
    let dir = args.str_or("artifacts", "artifacts");
    let cfg = args.str_or("model", "small");
    let cfg = if cfg == "auto" || cfg == "paper" { "small" } else { cfg };
    Engine::load(dir, cfg)
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "train" => cmd_train(&args),
        // Internal: one rank of `train --dist N` (the launcher spawns
        // these; not part of the public CLI surface).
        "dist-worker" => cmd_dist_worker(&args),
        "train-bench" => cmd_train_bench(&args),
        "translate" => cmd_translate(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "serve-load" => cmd_serve_load(&args),
        "sim" => cmd_sim(&args),
        "table1" => {
            let dims = ModelDims::paper();
            print!(
                "{}",
                report::table1(
                    args.usize("sentences14", 4000)?,
                    args.usize("sentences17", 8000)?,
                    &dims
                )
            );
            Ok(())
        }
        "table2" => {
            let exp = match args.str_or("model", "paper") {
                "paper" => Experiment {
                    model: ModelDims::paper(),
                    strategy: Strategy::Hybrid,
                    hw: HwConfig::default(),
                    train: TrainConfig::default(),
                    data: DataConfig::wmt14_sim(0),
                    artifacts_dir: "artifacts".into(),
                },
                _ => {
                    let engine = load_engine(&args)?;
                    build_experiment(&args, &engine)?
                }
            };
            print!("{}", report::table2(&exp));
            Ok(())
        }
        "table3" => {
            print!("{}", report::table3(&HwConfig::default()));
            if args.get("real").is_some() {
                let engine = load_engine(&args)?;
                let steps = args.usize("steps", 5)?;
                print!(
                    "\n{}",
                    report::table3_wallclock(&engine, &HwConfig::default(), steps)?
                );
            }
            Ok(())
        }
        "table4" => cmd_table4(&args),
        "table5" => cmd_table5(&args),
        "figure4" => cmd_figure4(&args),
        other => Err(anyhow!("unknown command `{other}`\n\n{HELP}")),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let dist = args.usize("dist", 1)?;
    if dist >= 2 {
        return cmd_train_dist(args, dist);
    }
    let engine = load_engine(args)?;
    let exp = build_experiment(args, &engine)?;
    println!(
        "training {} on {} ({} steps, batch {}, model `{}`)",
        exp.strategy.label(),
        exp.data.dataset,
        exp.train.steps,
        exp.model.batch,
        exp.model.name
    );
    let corpus = report::make_corpus(&exp.data, &exp.model);
    let mut batcher = report::make_batcher(&exp, &corpus)?;
    println!(
        "corpus: {} train batches, vocab {}, avg src len {:.1}, dropped {}",
        batcher.n_train_batches(),
        batcher.vocab.len(),
        batcher.avg_src_len(),
        batcher.dropped
    );
    let mut trainer = Trainer::new(&engine, &exp)?;
    trainer.sequential = args.get("sequential").is_some();
    if args.get("map-step").is_some() {
        trainer.set_step_mode(StepMode::Map);
    }
    trainer.set_bucket_bytes(args.usize("bucket-kib", 256)?.max(1) * 1024);
    let precision: SlabDtype =
        args.str_or("precision", "f32").parse().map_err(|e: String| anyhow!(e))?;
    trainer.set_precision(precision)?;
    if precision != SlabDtype::F32 {
        println!(
            "mixed precision: {precision} parameter/gradient slabs, dynamic loss scaling \
             (f32 master copy in the optimizer)"
        );
    }
    let replicas = args.usize("replicas", 1)?.max(1);
    let accum = args.usize("accum", 1)?.max(1);
    trainer.set_pipeline(replicas, accum);
    if let Some(dir) = args.get("ckpt-dir") {
        let every = args.usize("checkpoint-every", 25)?.max(1);
        let store = Retrying::new(LocalDir::new(dir)?, RetryPolicy::STORAGE);
        trainer.enable_async_checkpoint(Arc::new(store), every);
        println!("async checkpointing to {dir}/ every {every} steps (latest-pointer protocol)");
    }
    let resumed_at = if let Some(path) = args.get("resume") {
        let p = std::path::Path::new(path);
        if p.is_dir() {
            // A checkpoint *directory*: resolve its `latest` pointer to
            // the newest durable checkpoint — torn/unreferenced objects
            // from a crashed writer are never considered.
            let store = Retrying::new(LocalDir::new(p)?, RetryPolicy::STORAGE);
            let key = trainer.resume_latest(&store)?.ok_or_else(|| {
                anyhow!("--resume {path}: directory has no published checkpoint")
            })?;
            println!("resolved {path}/latest -> {key}");
        } else {
            trainer.resume(p)?;
        }
        // Fast-forward the deterministic batch stream past the shards
        // the checkpointed run already consumed (the checkpoint records
        // the count, so this is correct even if this run picks a
        // different --replicas/--accum) — with the same data flags as
        // the original run, the continuation is bitwise-exact.
        let consumed = trainer.micro_consumed();
        batcher.skip_train(consumed);
        println!(
            "resumed from {path} at step {} (batch stream fast-forwarded {consumed} micro-batches)",
            trainer.steps_done()
        );
        trainer.steps_done()
    } else {
        0
    };
    println!(
        "plan: {} steps on {} devices ({} executor, {} step engine), \
         {} replicas x {} accum (global batch {}), sim step time {:.4}s, \
         sim {:.0} src-tok/s",
        trainer.plan.steps.len(),
        trainer.plan.distinct_devices().len(),
        if trainer.sequential { "sequential" } else { "parallel" },
        match trainer.step_mode() {
            StepMode::Flat => format!("flat/{}KiB-bucket", trainer.bucket_bytes() / 1024),
            StepMode::Map => "map".to_string(),
        },
        replicas,
        accum,
        replicas * accum * exp.model.batch,
        trainer.step_sim.makespan,
        trainer.sim_tokens_per_sec(batcher.avg_src_len())
    );
    trainer.run(&mut batcher, |line| println!("{line}"))?;
    if let Some(ckpt) = args.get("ckpt") {
        trainer.save_checkpoint(std::path::Path::new(ckpt))?;
        if precision == SlabDtype::F32 {
            println!("checkpoint (v2: params + optimizer state) written to {ckpt}");
        } else {
            println!(
                "checkpoint (v3: params + optimizer state + {precision} loss-scale state) \
                 written to {ckpt}"
            );
        }
    }
    let st = engine.stats();
    println!(
        "engine: {} executions, {} compiled artifacts, {:.1}s exec, {:.1}s convert",
        st.executions,
        st.compile_count,
        st.exec_nanos as f64 / 1e9,
        st.convert_nanos as f64 / 1e9
    );
    println!(
        "uploads: {} ({:.1} MB); buffer reuse: {} hits, {:.1} MB re-upload avoided; \
         param uploads/step: {:.1} over {} replica banks ({:.1} MB total, \
         {} bucketed prime passes)",
        st.uploads,
        st.upload_bytes as f64 / 1e6,
        st.buffer_hits,
        st.upload_bytes_saved as f64 / 1e6,
        // Uploads happened in this process only: divide by the steps
        // this run executed, not the checkpoint's lifetime count.
        trainer.pipeline.upload_count() as f64
            / (trainer.steps_done() - resumed_at).max(1) as f64,
        trainer.pipeline.replicas(),
        trainer.pipeline.upload_bytes() as f64 / 1e6,
        trainer.pipeline.prime_count()
    );
    Ok(())
}

/// `train --dist N` launcher: spawn N `dist-worker` processes over
/// loopback TCP and multiplex their output. Rank 0 prints
/// `DIST-LISTEN <addr>` once its rendezvous socket is bound; the
/// launcher relays that address to the workers via `--dist-addr`.
/// Any rank exiting non-zero fails the whole run, named by rank.
fn cmd_train_dist(args: &Args, world: usize) -> Result<()> {
    use std::io::BufRead;
    use std::process::{Command, Stdio};

    // Validate the mode up front — better a flag error here than one
    // replicated N times from the children.
    let mode: DistMode = args.str_or("dist-mode", "ps").parse()?;
    if args.get("dist-supervise").is_some() {
        return cmd_train_dist_supervised(args, world, mode);
    }
    let exe = std::env::current_exe().context("resolve current executable")?;
    let forward: Vec<(String, String)> = args
        .flags
        .iter()
        .filter(|(k, _)| k.as_str() != "dist-addr" && k.as_str() != "dist-rank")
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    let spawn = |rank: usize, addr: Option<&str>| -> Result<std::process::Child> {
        let mut c = Command::new(&exe);
        c.arg("dist-worker");
        for (k, v) in &forward {
            c.arg(format!("--{k}")).arg(v);
        }
        c.arg("--dist-rank").arg(rank.to_string());
        if let Some(a) = addr {
            c.arg("--dist-addr").arg(a);
        }
        c.stdout(Stdio::piped()).stderr(Stdio::piped());
        c.spawn().with_context(|| format!("spawn rank {rank}"))
    };

    println!("launching {world} ranks over loopback TCP ({} mode)", mode.key());
    let mut rank0 = spawn(0, None)?;
    let mut r0_out = std::io::BufReader::new(rank0.stdout.take().expect("stdout piped"));
    let mut addr = None;
    let mut line = String::new();
    while addr.is_none() {
        line.clear();
        if r0_out.read_line(&mut line)? == 0 {
            break;
        }
        match line.trim().strip_prefix("DIST-LISTEN ") {
            Some(a) => addr = Some(a.to_string()),
            None => print!("[rank 0] {line}"),
        }
    }
    let addr = match addr {
        Some(a) => a,
        None => {
            // Rank 0 died before binding: reap it and surface stderr.
            let st = rank0.wait()?;
            let mut err = String::new();
            if let Some(mut e) = rank0.stderr.take() {
                use std::io::Read;
                let _ = e.read_to_string(&mut err);
            }
            return Err(anyhow!("rank 0 exited ({st}) before DIST-LISTEN:\n{err}"));
        }
    };

    let mut procs: Vec<(usize, std::process::Child)> = vec![(0, rank0)];
    for r in 1..world {
        procs.push((r, spawn(r, Some(&addr))?));
    }
    let mut statuses: Vec<(usize, std::process::ExitStatus)> = Vec::with_capacity(world);
    std::thread::scope(|scope| -> Result<()> {
        // Drain every child's pipes concurrently (a full pipe buffer
        // would otherwise deadlock a chatty rank against our wait).
        scope.spawn(move || pump_lines(0, Box::new(r0_out)));
        for (rank, child) in procs.iter_mut() {
            let rank = *rank;
            if let Some(out) = child.stdout.take() {
                scope.spawn(move || pump_lines(rank, Box::new(out)));
            }
            if let Some(err) = child.stderr.take() {
                scope.spawn(move || pump_lines(rank, Box::new(err)));
            }
        }
        for (rank, child) in procs.iter_mut() {
            let st = child.wait().with_context(|| format!("wait rank {rank}"))?;
            statuses.push((*rank, st));
        }
        Ok(())
    })?;
    let failed: Vec<String> = statuses
        .iter()
        .filter(|(_, st)| !st.success())
        .map(|(r, st)| format!("rank {r}: {st}"))
        .collect();
    if !failed.is_empty() {
        return Err(anyhow!("distributed run failed — {}", failed.join(", ")));
    }
    println!(
        "all {world} ranks finished ({} mode); every rank holds the same \
         parameters the single-process run would have produced",
        mode.key()
    );
    Ok(())
}

/// Copy a child pipe to our stdout line-by-line with a rank prefix.
fn pump_lines(rank: usize, rd: Box<dyn std::io::Read + Send>) {
    use std::io::BufRead;
    for line in std::io::BufReader::new(rd).lines().map_while(|l| l.ok()) {
        println!("[rank {rank}] {line}");
    }
}

/// [`pump_lines`], but `DIST-HB <hex>` heartbeat lines are decoded and
/// forwarded to the supervisor's monitor channel instead of printed.
fn pump_lines_supervised(
    rank: usize,
    rd: Box<dyn std::io::Read + Send>,
    beats: std::sync::mpsc::Sender<Vec<u8>>,
) {
    use std::io::BufRead;
    for line in std::io::BufReader::new(rd).lines().map_while(|l| l.ok()) {
        match line.strip_prefix("DIST-HB ") {
            Some(hex) => {
                if let Some(bytes) = hybridnmt::dist::supervisor::from_hex(hex.trim()) {
                    let _ = beats.send(bytes);
                }
            }
            None => println!("[rank {rank}] {line}"),
        }
    }
}

/// `train --dist N --dist-supervise`: the elastic process-mode
/// launcher. Each incarnation spawns the N `dist-worker` processes
/// with its generation (`--dist-gen`), monitors their `DIST-HB`
/// heartbeat lines and exit statuses, and on a failure kills the
/// survivors and relaunches — the next incarnation's workers resume
/// from the newest durable checkpoint in `--ckpt-dir`, replaying the
/// derived batch stream so the final parameters are bitwise-identical
/// to a fault-free run. The restart budget (`--max-restarts`) turns a
/// repeatedly-dying world into one typed error, never a hang.
fn cmd_train_dist_supervised(args: &Args, world: usize, mode: DistMode) -> Result<()> {
    use hybridnmt::dist::{supervise, LivenessPolicy, SupervisorOpts};

    let ckpt_dir = args
        .get("ckpt-dir")
        .ok_or_else(|| {
            anyhow!(
                "--dist-supervise requires --ckpt-dir DIR: relaunched worlds resume from \
                 its durable `latest` checkpoint"
            )
        })?
        .to_string();
    let max_restarts = args.usize("max-restarts", 3)? as u32;
    let tmo = args.usize("dist-timeout-ms", 10_000)?.max(1) as u64;
    let heartbeat_ms = args.usize("heartbeat-ms", (tmo / 4).max(1) as usize)?.max(1) as u64;
    let liveness = LivenessPolicy::new(heartbeat_ms, 4);
    let sup = SupervisorOpts { max_restarts, liveness, ..SupervisorOpts::default() };
    let store = Retrying::new(LocalDir::new(&ckpt_dir)?, RetryPolicy::STORAGE);
    let exe = std::env::current_exe().context("resolve current executable")?;
    let forward: Vec<(String, String)> = args
        .flags
        .iter()
        .filter(|(k, _)| !matches!(k.as_str(), "dist-addr" | "dist-rank" | "dist-gen"))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();

    println!(
        "supervised launch: {world} ranks over loopback TCP ({} mode), heartbeat \
         {heartbeat_ms} ms (deadline {} ms), restart budget {max_restarts}, durable \
         checkpoints in {ckpt_dir}/",
        mode.key(),
        liveness.deadline_ms()
    );
    let ((), recovery) = supervise("train --dist", &sup, |gen| {
        run_process_incarnation(&exe, &forward, world, gen, &liveness, &store)
    })?;
    if recovery.restarts > 0 {
        println!(
            "recovered: {} restart(s), {} lost step(s) re-run, {:.0} ms recovery wall-clock",
            recovery.restarts, recovery.lost_steps, recovery.recovery_ms
        );
        for (g, d) in &recovery.failures {
            println!("  incarnation {g}: {d}");
        }
    }
    println!(
        "all {world} ranks finished ({} mode) under supervision; every rank holds the \
         same parameters the fault-free run would have produced",
        mode.key()
    );
    Ok(())
}

/// Launch and monitor one process-world incarnation; see
/// [`cmd_train_dist_supervised`]. Failures the budget can absorb come
/// back as `Incarnation::Failed`; launch/config problems (rank 0 dead
/// before its rendezvous bind) are hard errors.
fn run_process_incarnation(
    exe: &std::path::Path,
    forward: &[(String, String)],
    world: usize,
    gen: u32,
    liveness: &hybridnmt::dist::LivenessPolicy,
    store: &dyn hybridnmt::storage::Storage,
) -> hybridnmt::dist::DistResult<hybridnmt::dist::Incarnation<()>> {
    use hybridnmt::dist::{latest_durable_step, DistError, FailureCause, HeartbeatMonitor, Incarnation};
    use std::io::BufRead;
    use std::process::{Command, Stdio};

    let perm = |what: &str, e: &dyn std::fmt::Display| DistError::permanent(format!("{what}: {e}"));
    let spawn = |rank: usize, addr: Option<&str>| -> Result<std::process::Child, DistError> {
        let mut c = Command::new(exe);
        c.arg("dist-worker");
        for (k, v) in forward {
            c.arg(format!("--{k}")).arg(v);
        }
        c.arg("--dist-rank").arg(rank.to_string());
        c.arg("--dist-gen").arg(gen.to_string());
        if let Some(a) = addr {
            c.arg("--dist-addr").arg(a);
        }
        c.stdout(Stdio::piped()).stderr(Stdio::piped());
        c.spawn().map_err(|e| perm(&format!("spawn rank {rank}"), &e))
    };

    println!("[supervisor] launching incarnation {gen} ({world} ranks)");
    let mut monitor = HeartbeatMonitor::detached(world, gen, *liveness);
    let (beat_tx, beat_rx) = std::sync::mpsc::channel::<Vec<u8>>();

    let mut rank0 = spawn(0, None)?;
    let mut r0_out = std::io::BufReader::new(rank0.stdout.take().expect("stdout piped"));
    let mut addr = None;
    let mut line = String::new();
    while addr.is_none() {
        line.clear();
        if r0_out.read_line(&mut line).map_err(|e| perm("read rank 0 stdout", &e))? == 0 {
            break;
        }
        match line.trim().strip_prefix("DIST-LISTEN ") {
            Some(a) => addr = Some(a.to_string()),
            None => print!("[rank 0] {line}"),
        }
    }
    let addr = match addr {
        Some(a) => a,
        None => {
            // Dead before the rendezvous bind: nothing a relaunch can
            // fix (bad flags, bad model dir) — fail the whole run.
            let st = rank0.wait().map_err(|e| perm("reap rank 0", &e))?;
            let mut err = String::new();
            if let Some(mut e) = rank0.stderr.take() {
                use std::io::Read;
                let _ = e.read_to_string(&mut err);
            }
            return Err(DistError::permanent(format!(
                "rank 0 exited ({st}) before DIST-LISTEN:\n{err}"
            )));
        }
    };

    let mut procs: Vec<(usize, std::process::Child)> = vec![(0, rank0)];
    for r in 1..world {
        match spawn(r, Some(&addr)) {
            Ok(c) => procs.push((r, c)),
            Err(e) => {
                for (_, child) in procs.iter_mut() {
                    let _ = child.kill();
                    let _ = child.wait();
                }
                return Err(e);
            }
        }
    }

    let mut exit: Option<(usize, i32)> = None;
    let mut hb_timeout: Option<usize> = None;
    let mut finished = vec![false; world];
    let scope_result: Result<(), DistError> = std::thread::scope(|scope| {
        let tx0 = beat_tx.clone();
        scope.spawn(move || pump_lines_supervised(0, Box::new(r0_out), tx0));
        for (rank, child) in procs.iter_mut() {
            let rank = *rank;
            if let Some(out) = child.stdout.take() {
                let tx = beat_tx.clone();
                scope.spawn(move || pump_lines_supervised(rank, Box::new(out), tx));
            }
            if let Some(err) = child.stderr.take() {
                scope.spawn(move || pump_lines(rank, Box::new(err)));
            }
        }
        drop(beat_tx);
        loop {
            while let Ok(bytes) = beat_rx.try_recv() {
                monitor
                    .note_bytes(&bytes, std::time::Instant::now())
                    .map_err(|e| perm("heartbeat stream", &e))?;
            }
            let mut all_done = true;
            for (rank, child) in procs.iter_mut() {
                if finished[*rank] {
                    continue;
                }
                match child.try_wait() {
                    Ok(Some(st)) => {
                        finished[*rank] = true;
                        if !st.success() && exit.is_none() {
                            exit = Some((*rank, st.code().unwrap_or(-1)));
                        }
                    }
                    Ok(None) => all_done = false,
                    Err(e) => return Err(perm(&format!("poll rank {rank}"), &e)),
                }
            }
            if all_done || exit.is_some() {
                break;
            }
            let now = std::time::Instant::now();
            // Silence only counts once a rank has beaten (before that it
            // is still building its engine/corpus); a rank that never
            // beats at all is caught by a 10×-deadline launch grace.
            hb_timeout = monitor.dead_ranks(now).into_iter().find(|&r| {
                !finished[r]
                    && (monitor.has_beaten(r)
                        || monitor.age_ms(now) > 10 * liveness.deadline_ms())
            });
            if hb_timeout.is_some() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        // Teardown: anything still running is killed — the relaunch
        // must never race a half-dead predecessor (its frames carry the
        // old generation and are dropped at the wire anyway).
        for (rank, child) in procs.iter_mut() {
            if !finished[*rank] {
                let _ = child.kill();
                let _ = child.wait();
                finished[*rank] = true;
            }
        }
        Ok(())
    });
    scope_result?;

    let lost = || -> hybridnmt::dist::DistResult<u64> {
        Ok(monitor.max_step().saturating_sub(latest_durable_step(store)?))
    };
    if let Some(r) = hb_timeout {
        return Ok(Incarnation::Failed {
            cause: FailureCause::HeartbeatTimeout { rank: r },
            detail: format!(
                "incarnation {gen}: rank {r} silent past the {} ms deadline, world killed",
                liveness.deadline_ms()
            ),
            lost_steps: lost()?,
        });
    }
    if let Some((r, code)) = exit {
        return Ok(Incarnation::Failed {
            cause: FailureCause::ProcessExit { rank: r, code },
            detail: format!("incarnation {gen}: rank {r} process exited with code {code}"),
            lost_steps: lost()?,
        });
    }
    Ok(Incarnation::Done(()))
}

/// Parse `--dist-die RANK@STEP` (that rank hard-exits just before the
/// 1-based step).
fn parse_dist_die(v: &str) -> Result<(usize, u64)> {
    let (r, s) = v
        .split_once('@')
        .ok_or_else(|| anyhow!("--dist-die wants RANK@STEP, got `{v}`"))?;
    Ok((
        r.parse().with_context(|| format!("--dist-die rank `{r}`"))?,
        s.parse().with_context(|| format!("--dist-die step `{s}`"))?,
    ))
}

/// One rank of a `train --dist N` job (spawned by [`cmd_train_dist`]).
fn cmd_dist_worker(args: &Args) -> Result<()> {
    use std::io::Write;

    let world = args.usize("dist", 0)?;
    if world < 2 {
        return Err(anyhow!("dist-worker needs --dist >= 2"));
    }
    let rank = args.usize("dist-rank", 0)?;
    if rank >= world {
        return Err(anyhow!("--dist-rank {rank} outside world {world}"));
    }
    let mode: DistMode = args.str_or("dist-mode", "ps").parse()?;
    let ring = mode == DistMode::Replicated;
    let tmo = args.usize("dist-timeout-ms", 10_000)?.max(1) as u64;
    // The incarnation generation (supervised relaunches): stamped into
    // every frame this rank sends, so zombies from a dead incarnation
    // are dropped at the wire layer of the fresh world.
    let gen = args.usize("dist-gen", 0)? as u32;
    let opts = CommOpts {
        read_timeout_ms: tmo,
        connect_timeout_ms: tmo,
        generation: gen,
        ..CommOpts::default()
    };

    // Rank 0 publishes its rendezvous address *before* the (slow)
    // engine/corpus build so the launcher can start the workers; every
    // rank then builds in parallel and the rendezvous skew stays well
    // inside the connect timeout.
    let listener = if rank == 0 {
        let l = std::net::TcpListener::bind("127.0.0.1:0").context("bind rendezvous listener")?;
        println!("DIST-LISTEN {}", l.local_addr()?);
        std::io::stdout().flush().ok();
        Some(l)
    } else {
        None
    };

    let engine = load_engine(args)?;
    let exp = build_experiment(args, &engine)?;
    let replicas = args.usize("replicas", 1)?.max(1);
    let accum = args.usize("accum", 1)?.max(1);
    let steps = exp.train.steps;
    let mut spec = hybridnmt::dist::RankSpec::new(exp.clone(), mode, replicas, accum, steps);
    spec.sequential = args.get("sequential").is_some();
    spec.bucket_bytes = Some(args.usize("bucket-kib", 256)?.max(1) * 1024);
    spec.precision = args.str_or("precision", "f32").parse().map_err(|e: String| anyhow!(e))?;
    if let Some(die) = args.get("dist-die") {
        let (r, s) = parse_dist_die(die)?;
        if r == rank {
            spec.die_at_step = Some(s);
            spec.die_hard = true;
        }
    }
    let local = spec.local_shards();

    // Every rank derives the same global micro-batch stream and trains
    // on its contiguous block of each step (see dist::driver).
    let corpus = report::make_corpus(&exp.data, &exp.model);
    let mut batcher = report::make_batcher(&exp, &corpus)?;
    let stream: Vec<_> = (0..steps * world * local).map(|_| batcher.next_train()).collect();

    let transport = match listener {
        Some(l0) => TcpTransport::rank0(l0, world, ring, opts.clone())?,
        None => {
            let addr = args
                .get("dist-addr")
                .ok_or_else(|| anyhow!("--dist-addr required for rank > 0"))?;
            let addr: std::net::SocketAddr =
                addr.parse().with_context(|| format!("--dist-addr {addr}"))?;
            TcpTransport::worker(rank, world, addr, ring, opts.clone())?
        }
    };
    let comm = DistComm::new(Box::new(transport), mode, local, opts.backoff.clone())?;
    println!(
        "rank {rank}/{world} up ({} mode, incarnation {gen}): {steps} steps, {replicas} \
         replicas x {accum} accum, global batch {}",
        mode.key(),
        world * local * exp.model.batch
    );
    // Supervised-run context: durable checkpoints (rank 0 publishes,
    // every rank resumes — valid because params are bitwise-identical
    // across ranks) and per-step stdout heartbeats for the launcher.
    let mut ctx = hybridnmt::dist::RankCtx { gen, ..Default::default() };
    if let Some(dir) = args.get("ckpt-dir") {
        let every = args.usize("checkpoint-every", 25)?.max(1);
        let store: Arc<dyn hybridnmt::storage::Storage> =
            Arc::new(Retrying::new(LocalDir::new(dir)?, RetryPolicy::STORAGE));
        ctx.store = Some(store);
        ctx.ckpt_every = every;
        if rank == 0 {
            println!("rank 0 checkpoints to {dir}/ every {every} steps (latest-pointer protocol)");
        }
    }
    if args.get("dist-supervise").is_some() {
        ctx.beat = Some(hybridnmt::dist::HeartbeatTx::stdout(rank as u32, gen));
    }
    let run = hybridnmt::dist::train_rank_ctx(&engine, &spec, &comm, &stream, &ctx)?;
    let last = run.stats.last();
    println!(
        "rank {rank} done: {} steps, final loss/tok {:.6}, ppl {:.3}",
        run.stats.len(),
        last.map(|s| s.loss_per_tok).unwrap_or(f64::NAN),
        last.map(|s| s.ppl).unwrap_or(f64::NAN)
    );
    Ok(())
}

/// Training-throughput sweep (the acceptance gate of the flat-slab
/// overlapped-reduce engine): time `--steps` optimizer steps at each
/// replicas × accum configuration — on **both** step engines (the
/// flat-slab default and the map-based PR-4 reference) — after one
/// untimed warmup step per config (artifact compilation + first
/// parameter upload). Every config starts from the same seed and the
/// same batch stream, so configurations with equal `replicas × accum`
/// consume identical global batches — their first timed losses are
/// asserted bitwise equal *across engines too*, the train-side
/// analogue of serve-bench's token-identity gate. Rows report
/// `overlap_pct` (share of the reduce hidden under compute) and
/// `allocs_per_step` (f32 buffer churn) so the flat engine's wins are
/// regression-tracked. Writes `BENCH_train.json` +
/// `results/train_bench.{txt,csv}`.
fn cmd_train_bench(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let exp = build_experiment(args, &engine)?;
    let corpus = report::make_corpus(&exp.data, &exp.model);
    let steps = args.usize("steps", 8)?.max(1);
    let max_rep = args.usize("replicas", 4)?.max(1);
    let max_accum = args.usize("accum", 4)?.max(1);
    let bucket_bytes = args.usize("bucket-kib", 256)?.max(1) * 1024;
    let mut replica_counts = vec![1usize];
    let mut rv = 2;
    while rv <= max_rep {
        replica_counts.push(rv);
        rv *= 2;
    }
    if *replica_counts.last().unwrap() != max_rep {
        replica_counts.push(max_rep);
    }
    let accums: Vec<usize> = if max_accum > 1 { vec![1, max_accum] } else { vec![1] };
    // `--precision f32,bf16` adds 16-bit rows next to the f32 sweep.
    // The map reference engine is f32-only, so 16-bit precisions run on
    // the flat engine alone.
    let precisions: Vec<SlabDtype> = args
        .str_or("precision", "f32")
        .split(',')
        .map(|s| s.trim().parse::<SlabDtype>().map_err(|e: String| anyhow!(e)))
        .collect::<Result<Vec<_>>>()?;
    let mut engine_cfgs: Vec<(StepMode, SlabDtype)> = Vec::new();
    for &prec in &precisions {
        engine_cfgs.push((StepMode::Flat, prec));
        if prec == SlabDtype::F32 {
            engine_cfgs.push((StepMode::Map, prec));
        }
    }

    let mut rows = Vec::new();
    // First timed loss per (global-batch size, precision): equal-sized
    // f32 configs must agree bitwise (same shards, same fixed-order
    // tree) — including flat vs map rows of the same config. 16-bit
    // rows gate bitwise against each other and within 10% of the f32
    // loss (the loss-parity gate of the mixed-precision path).
    let mut loss_gate: std::collections::BTreeMap<(usize, u8), f64> =
        std::collections::BTreeMap::new();
    let ckpt_every = args.usize("checkpoint-every", 2)?.max(1);
    for &replicas in &replica_counts {
        for &accum in &accums {
            for &(mode, prec) in &engine_cfgs {
                let label = match mode {
                    StepMode::Flat => "flat",
                    StepMode::Map => "map",
                };
                let label = if prec == SlabDtype::F32 {
                    label.to_string()
                } else {
                    format!("{label}-{prec}")
                };
                let mut batcher = report::make_batcher(&exp, &corpus)?;
                let mut trainer = Trainer::new(&engine, &exp)?;
                trainer.sequential = args.get("sequential").is_some();
                trainer.set_step_mode(mode);
                trainer.set_bucket_bytes(bucket_bytes);
                trainer.set_precision(prec)?;
                trainer.set_pipeline(replicas, accum);
                let per_step = trainer.pipeline.micro_per_step();
                // Warmup (compilation, first uploads) outside the timing.
                let warm: Vec<_> = (0..per_step).map(|_| batcher.next_train()).collect();
                trainer.train_step_micro(&warm)?;
                let uploads0 = trainer.pipeline.upload_count();
                // Async checkpointing is part of the timed sweep: a real
                // LocalDir backend (fsync + rename per publish) so the
                // ~0-stall claim is measured against actual disk I/O.
                let ck_dir = std::env::temp_dir()
                    .join(format!("hynmt_train_bench_ckpt_r{replicas}_a{accum}_{label}"));
                let _ = std::fs::remove_dir_all(&ck_dir);
                trainer.enable_async_checkpoint(
                    Arc::new(Retrying::new(LocalDir::new(&ck_dir)?, RetryPolicy::STORAGE)),
                    ckpt_every,
                );

                let (mut reduce_s, mut overlap_s, mut apply_s, mut stall_s) =
                    (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                let mut ckpt_stall = 0.0f64;
                let mut tokens = 0.0f64;
                let mut allocs = 0u64;
                let mut grad_bytes = 0u64;
                let mut ovf_skips = 0u64;
                let mut first_loss = f64::NAN;
                let mut last_loss = f64::NAN;
                let t0 = std::time::Instant::now();
                with_prefetch(&mut batcher, steps * per_step, per_step, |pre| {
                    for i in 0..steps {
                        let micro: Vec<_> =
                            (0..per_step).map(|_| pre.next()).collect::<Result<_>>()?;
                        let stall = pre.take_stall();
                        let st = trainer.train_step_micro(&micro)?;
                        let (ck_stall, _) = trainer.tick_checkpoint()?;
                        ckpt_stall += ck_stall;
                        reduce_s += st.reduce_seconds;
                        overlap_s += st.reduce_overlap_seconds;
                        apply_s += st.apply_seconds;
                        stall_s += stall;
                        tokens += st.src_tokens;
                        allocs += st.allocs;
                        grad_bytes += st.grad_bytes;
                        ovf_skips += st.overflow_skipped as u64;
                        if i == 0 {
                            first_loss = st.loss_per_tok;
                        }
                        last_loss = st.loss_per_tok;
                    }
                    Ok(())
                })?;
                let wall = t0.elapsed().as_secs_f64();
                // The final blocking flush sits outside the timed loop —
                // steady-state stall is the claim, not shutdown latency.
                let ck = trainer.finalize_checkpoints()?.unwrap_or_default();
                let _ = std::fs::remove_dir_all(&ck_dir);
                let ckpt_bytes_per_s =
                    if ck.write_seconds > 0.0 { ck.bytes as f64 / ck.write_seconds } else { 0.0 };
                match loss_gate.get(&(per_step, prec.code())) {
                    Some(expect) if expect.to_bits() != first_loss.to_bits() => {
                        return Err(anyhow!(
                            "training diverged from the equal-batch reference: {replicas} \
                             replicas x {accum} accum ({label}) got loss {first_loss}, \
                             expected {expect}"
                        ));
                    }
                    Some(_) => {}
                    None => {
                        loss_gate.insert((per_step, prec.code()), first_loss);
                    }
                }
                if prec != SlabDtype::F32 {
                    // Loss-parity gate: a 16-bit run of the same global
                    // batch must land within 10% of the f32 loss.
                    if let Some(f32_first) = loss_gate.get(&(per_step, SlabDtype::F32.code())) {
                        let rel = (first_loss - f32_first).abs() / f32_first.abs().max(1e-9);
                        if !(rel < 0.1) {
                            return Err(anyhow!(
                                "{prec} loss parity gate failed: {replicas} replicas x {accum} \
                                 accum got first loss {first_loss}, f32 reference {f32_first} \
                                 (relative gap {rel:.4} >= 0.1)"
                            ));
                        }
                    }
                }
                let sn = steps as f64;
                let overlap_pct =
                    if reduce_s > 0.0 { 100.0 * overlap_s / reduce_s } else { 0.0 };
                println!(
                    "replicas {replicas} x accum {accum} [{label}]: {:.1} ms/step \
                     (reduce {:.1} [{overlap_pct:.0}% hidden] apply {:.1} stall {:.1} \
                     ck-stall {:.2}), {:.1} src tok/s, {:.0} allocs/step, \
                     {} ckpt ({:.1} MB/s)",
                    wall / sn * 1e3,
                    reduce_s / sn * 1e3,
                    apply_s / sn * 1e3,
                    stall_s / sn * 1e3,
                    ckpt_stall / sn * 1e3,
                    per_sec(tokens, wall),
                    allocs as f64 / sn,
                    ck.written,
                    ckpt_bytes_per_s / 1e6,
                );
                rows.push(report::TrainBenchRow {
                    replicas,
                    accum,
                    flat: mode == StepMode::Flat,
                    steps,
                    global_batch: per_step * exp.model.batch,
                    step_s: wall / sn,
                    reduce_s: reduce_s / sn,
                    overlap_pct,
                    apply_s: apply_s / sn,
                    stall_s: stall_s / sn,
                    src_tok_per_s: per_sec(tokens, wall),
                    loss_per_tok: last_loss,
                    uploads_per_step: (trainer.pipeline.upload_count() - uploads0) as f64 / sn,
                    allocs_per_step: allocs as f64 / sn,
                    ckpt_stall_s: ckpt_stall / sn,
                    ckpt_bytes_per_s,
                    dist_world: 0,
                    dist_mode: String::new(),
                    precision: prec,
                    bytes_per_step: grad_bytes as f64 / sn,
                    overflow_skips: ovf_skips,
                    chaos: false,
                    restarts: 0,
                    recovery_ms: 0.0,
                    lost_steps: 0,
                });
            }
        }
    }
    // Distributed rows: an N-rank in-process world per collective mode
    // (fake transport — the full wire encode/decode without sockets).
    // Per-rank warmup/compilation lands inside the timed window, so
    // these rows track collective cost trends, not absolute parity
    // with the single-process rows; the correctness gate here is the
    // two modes agreeing bitwise on the first step's loss.
    let dist_world = args.usize("dist", 0)?;
    if dist_world >= 2 {
        let mut first_losses = Vec::new();
        for mode in [DistMode::Ps, DistMode::Replicated] {
            let mut batcher = report::make_batcher(&exp, &corpus)?;
            let spec = {
                let mut s = hybridnmt::dist::RankSpec::new(exp.clone(), mode, 1, 1, steps);
                s.sequential = args.get("sequential").is_some();
                s.bucket_bytes = Some(bucket_bytes);
                s
            };
            let local = spec.local_shards();
            let stream: Vec<_> =
                (0..steps * dist_world * local).map(|_| batcher.next_train()).collect();
            let specs = vec![spec; dist_world];
            let scripts = vec![hybridnmt::dist::FaultScript::clean(); dist_world];
            let t0 = std::time::Instant::now();
            let runs = hybridnmt::dist::run_fake_world(
                &engine,
                &specs,
                scripts,
                CommOpts::default(),
                &stream,
            );
            let wall = t0.elapsed().as_secs_f64();
            let mut tokens = 0.0f64;
            let mut rank0_stats = None;
            for (r, run) in runs.into_iter().enumerate() {
                let run =
                    run.map_err(|e| anyhow!("dist bench rank {r} ({}): {e:#}", mode.key()))?;
                tokens += run.stats.iter().map(|s| s.src_tokens).sum::<f64>();
                if r == 0 {
                    rank0_stats = Some(run.stats);
                }
            }
            let stats = rank0_stats.expect("world >= 2 always has a rank 0");
            let sn = steps as f64;
            let reduce_s: f64 = stats.iter().map(|s| s.reduce_seconds).sum();
            let overlap_s: f64 = stats.iter().map(|s| s.reduce_overlap_seconds).sum();
            let apply_s: f64 = stats.iter().map(|s| s.apply_seconds).sum();
            let first = stats.first().map(|s| s.loss_per_tok).unwrap_or(f64::NAN);
            let last = stats.last().map(|s| s.loss_per_tok).unwrap_or(f64::NAN);
            first_losses.push(first);
            println!(
                "dist {dist_world} [{}]: {:.1} ms/step, {:.1} src tok/s (global), loss/tok {:.4}",
                mode.key(),
                wall / sn * 1e3,
                per_sec(tokens, wall),
                last
            );
            rows.push(report::TrainBenchRow {
                replicas: 1,
                accum: 1,
                flat: true,
                steps,
                global_batch: dist_world * exp.model.batch,
                step_s: wall / sn,
                reduce_s: reduce_s / sn,
                overlap_pct: if reduce_s > 0.0 { 100.0 * overlap_s / reduce_s } else { 0.0 },
                apply_s: apply_s / sn,
                stall_s: 0.0,
                src_tok_per_s: per_sec(tokens, wall),
                loss_per_tok: last,
                uploads_per_step: 0.0,
                allocs_per_step: stats.iter().map(|s| s.allocs).sum::<u64>() as f64 / sn,
                ckpt_stall_s: 0.0,
                ckpt_bytes_per_s: 0.0,
                dist_world,
                dist_mode: mode.key().to_string(),
                precision: SlabDtype::F32,
                bytes_per_step: stats.iter().map(|s| s.grad_bytes).sum::<u64>() as f64 / sn,
                overflow_skips: stats.iter().filter(|s| s.overflow_skipped).count() as u64,
                chaos: false,
                restarts: 0,
                recovery_ms: 0.0,
                lost_steps: 0,
            });
        }
        if first_losses.len() == 2 && first_losses[0].to_bits() != first_losses[1].to_bits() {
            return Err(anyhow!(
                "ps and replicated modes disagree on the first dist loss: {} vs {}",
                first_losses[0],
                first_losses[1]
            ));
        }
        println!("dist modes agree bitwise on the first-step loss ({dist_world} ranks)");
    }
    // Supervised chaos rows: per collective mode, a world with a
    // scripted rank kill runs under the elastic supervisor (durable
    // checkpoints every step, restart budget 3) and its recovered
    // final parameters gate bitwise against a fault-free world on the
    // identical stream — the recovery-cost columns quantify what the
    // equivalence cost.
    if args.get("chaos").is_some() {
        use hybridnmt::dist::{
            run_supervised_world, FaultScript, RankSpec, ScheduledDeath, SupervisorOpts,
            WorldKind,
        };
        if dist_world < 2 {
            return Err(anyhow!("--chaos needs --dist N with N >= 2"));
        }
        for mode in [DistMode::Ps, DistMode::Replicated] {
            let mut batcher = report::make_batcher(&exp, &corpus)?;
            let spec0 = {
                let mut s = RankSpec::new(exp.clone(), mode, 1, 1, steps);
                s.sequential = args.get("sequential").is_some();
                s.bucket_bytes = Some(bucket_bytes);
                s
            };
            let local = spec0.local_shards();
            let stream: Vec<_> =
                (0..steps * dist_world * local).map(|_| batcher.next_train()).collect();
            // Fault-free reference world: the params the recovered run
            // must reproduce bit for bit.
            let clean = hybridnmt::dist::run_fake_world(
                &engine,
                &vec![spec0.clone(); dist_world],
                vec![FaultScript::clean(); dist_world],
                CommOpts::fast(),
                &stream,
            );
            let mut ref_params = None;
            for (r, run) in clean.into_iter().enumerate() {
                let run =
                    run.map_err(|e| anyhow!("chaos reference rank {r} ({}): {e:#}", mode.key()))?;
                if r == 0 {
                    ref_params = Some(run.params);
                }
            }
            let ref_params = ref_params.expect("world >= 2 always has a rank 0");
            // The chaos world: rank 1 soft-dies just before step 2 of
            // the initial incarnation; the supervisor relaunches from
            // the newest durable checkpoint.
            let mut specs = vec![spec0; dist_world];
            specs[1].die_script =
                vec![ScheduledDeath { gen: 0, step: (steps as u64).min(2), hard: false }];
            let ck_dir =
                std::env::temp_dir().join(format!("hynmt_train_bench_chaos_{}", mode.key()));
            let _ = std::fs::remove_dir_all(&ck_dir);
            let store: Arc<dyn hybridnmt::storage::Storage> =
                Arc::new(Retrying::new(LocalDir::new(&ck_dir)?, RetryPolicy::STORAGE));
            let t0 = std::time::Instant::now();
            let out = run_supervised_world(
                &engine,
                &specs,
                WorldKind::Fake,
                &CommOpts::fast(),
                &SupervisorOpts::fast(3),
                store,
                1,
                &stream,
                vec![FaultScript::clean(); dist_world],
            )?;
            let wall = t0.elapsed().as_secs_f64();
            let _ = std::fs::remove_dir_all(&ck_dir);
            for (name, t) in &ref_params {
                let g = out.ranks[0]
                    .params
                    .get(name)
                    .ok_or_else(|| anyhow!("chaos run missing param `{name}`"))?;
                let same = t.data().len() == g.data().len()
                    && t.data().iter().zip(g.data()).all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err(anyhow!(
                        "chaos recovery diverged: param `{name}` differs bitwise from the \
                         fault-free run ({} mode)",
                        mode.key()
                    ));
                }
            }
            let rec = &out.recovery;
            println!(
                "chaos {dist_world} [{}]: {} restart(s), {:.0} ms recovery, {} lost step(s) \
                 re-run; recovered params bitwise-equal to the fault-free run",
                mode.key(),
                rec.restarts,
                rec.recovery_ms,
                rec.lost_steps
            );
            let stats = &out.ranks[0].stats;
            let sn = steps as f64;
            let reduce_s: f64 = stats.iter().map(|s| s.reduce_seconds).sum();
            let overlap_s: f64 = stats.iter().map(|s| s.reduce_overlap_seconds).sum();
            rows.push(report::TrainBenchRow {
                replicas: 1,
                accum: 1,
                flat: true,
                steps,
                global_batch: dist_world * exp.model.batch,
                step_s: wall / sn,
                reduce_s: reduce_s / sn,
                overlap_pct: if reduce_s > 0.0 { 100.0 * overlap_s / reduce_s } else { 0.0 },
                apply_s: stats.iter().map(|s| s.apply_seconds).sum::<f64>() / sn,
                stall_s: 0.0,
                src_tok_per_s: per_sec(
                    stats.iter().map(|s| s.src_tokens).sum::<f64>() * dist_world as f64,
                    wall,
                ),
                loss_per_tok: stats.last().map(|s| s.loss_per_tok).unwrap_or(f64::NAN),
                uploads_per_step: 0.0,
                allocs_per_step: stats.iter().map(|s| s.allocs).sum::<u64>() as f64 / sn,
                ckpt_stall_s: 0.0,
                ckpt_bytes_per_s: 0.0,
                dist_world,
                dist_mode: mode.key().to_string(),
                precision: SlabDtype::F32,
                bytes_per_step: stats.iter().map(|s| s.grad_bytes).sum::<u64>() as f64 / sn,
                overflow_skips: 0,
                chaos: true,
                restarts: rec.restarts,
                recovery_ms: rec.recovery_ms,
                lost_steps: rec.lost_steps,
            });
        }
        // The supervisor's counters/histograms, for scrape-side
        // alerting parity with the serve-side dump. A separate file so
        // serve-bench's results/metrics.prom is never clobbered.
        std::fs::create_dir_all("results").ok();
        write_file_atomic(
            std::path::Path::new("results/metrics_train.prom"),
            hybridnmt::metrics::Registry::global().render().as_bytes(),
        )
        .context("write results/metrics_train.prom")?;
        println!("wrote results/metrics_train.prom (dist_supervisor_* recovery counters)");
    }
    print!("\n{}", report::train_table(&rows));
    println!("wrote BENCH_train.json");
    Ok(())
}

fn cmd_translate(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?;
    let strategy: Strategy = args.str_or("strategy", "hybrid").parse()?;
    let input_feeding = strategy.uses_input_feeding();
    let exp = build_experiment(args, &engine)?;
    let corpus = report::make_corpus(&exp.data, &exp.model);
    let batcher = report::make_batcher(&exp, &corpus)?;
    let alpha: f64 = args.str_or("alpha", "1.0").parse()?;
    let beam = args.usize("beam", 6)?;
    // Same beam envelope on both paths: the batched engine could pack
    // wider, but beams beyond the artifact decode width have no
    // single-sentence reference to be checked against.
    if beam == 0 || beam > engine.dims().beam {
        return Err(anyhow!(
            "--beam {beam} outside this model's decode width 1..={}",
            engine.dims().beam
        ));
    }
    let cfg = BeamConfig {
        beam,
        max_len: engine.dims().max_tgt,
        norm: LengthNorm::Marian { alpha },
    };
    let batch = args.usize("batch", 1)?;
    let devices = args.usize("devices", 1)?;
    let n = args.usize("n", 50)?.min(batcher.test.len());
    let srcs: Vec<Vec<i32>> = batcher.test[..n].iter().map(|e| e.src.clone()).collect();

    let hyps: Vec<Vec<i32>> = if batch > 1 || devices > 1 {
        // Batched multi-device engine: checkpoint parameters uploaded
        // once into a bank, encoder state device-resident per group.
        let (params, bank) = checkpoint::load_resident(std::path::Path::new(ckpt), &engine)?;
        let opts = DecodeOptions { batch, devices };
        let (hyps, stats) =
            translate_corpus(&engine, &params, &bank, input_feeding, &srcs, &cfg, &opts)?;
        println!(
            "batched decode: {} sentences in {:.2}s = {:.2} sent/s \
             (batch {batch}, {devices} workers, {} decode steps; \
             param uploads/hits {}/{}, state uploads/hits {}/{})\n",
            stats.sentences,
            stats.wall_s,
            stats.sentences_per_sec(),
            stats.decode_steps,
            stats.param_uploads,
            stats.param_hits,
            stats.state_uploads,
            stats.state_hits
        );
        hyps
    } else {
        let params = checkpoint::load(std::path::Path::new(ckpt))?;
        let decoder = Decoder::new(&engine, &params, input_feeding);
        srcs.iter().map(|s| decoder.translate(s, &cfg)).collect::<Result<_>>()?
    };

    let mut pairs = Vec::new();
    for (e, hyp) in batcher.test[..n].iter().zip(&hyps) {
        let hyp_s = batcher.vocab.decode(hyp);
        let ref_s = batcher.vocab.decode(&e.tgt);
        println!("SRC: {}", batcher.vocab.decode(&e.src));
        println!("HYP: {hyp_s}");
        println!("REF: {ref_s}\n");
        pairs.push((hyp_s, ref_s));
    }
    println!("test BLEU over {n} sentences: {:.2}", corpus_bleu(&pairs));
    Ok(())
}

/// Shared setup of the two serving commands: engine + encoded test
/// set, checkpoint-or-random parameters behind a resident bank, and
/// the beam configuration (validated against the model decode width).
struct ServeSetup {
    engine: Engine,
    input_feeding: bool,
    batcher: hybridnmt::data::Batcher,
    params: std::collections::BTreeMap<String, hybridnmt::tensor::Tensor>,
    bank: ParamBank,
    cfg: BeamConfig,
}

fn serve_setup(args: &Args) -> Result<ServeSetup> {
    let engine = load_engine(args)?;
    let strategy: Strategy = args.str_or("strategy", "hybrid").parse()?;
    let input_feeding = strategy.uses_input_feeding();
    let exp = build_experiment(args, &engine)?;
    let corpus = report::make_corpus(&exp.data, &exp.model);
    let batcher = report::make_batcher(&exp, &corpus)?;
    // Throughput/latency do not depend on the weight values, so both
    // serving benches run fine without a trained checkpoint.
    let (params, bank) = match args.get("ckpt") {
        Some(p) => checkpoint::load_resident(std::path::Path::new(p), &engine)?,
        None => (init_params(&exp, input_feeding), ParamBank::new()),
    };
    let beam = args.usize("beam", 4)?;
    if beam == 0 || beam > engine.dims().beam {
        return Err(anyhow!(
            "--beam {beam} outside this model's decode width 1..={}",
            engine.dims().beam
        ));
    }
    let cfg = BeamConfig {
        beam,
        max_len: engine.dims().max_tgt,
        norm: LengthNorm::Marian { alpha: args.str_or("alpha", "1.0").parse()? },
    };
    Ok(ServeSetup { engine, input_feeding, batcher, params, bank, cfg })
}

/// Sustained-translation throughput: the acceptance gate for the
/// batched inference engine. Decodes the same sentence set with the
/// single-sentence reference and the batched engine at batch {1, N} ×
/// devices {1, 2, .., D}, verifies token-identity, and writes
/// `BENCH_decode.json`.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    let s = serve_setup(args)?;
    let n = args.usize("n", 64)?.min(s.batcher.test.len());
    let srcs: Vec<Vec<i32>> = s.batcher.test[..n].iter().map(|e| e.src.clone()).collect();

    let batch = args.usize("batch", 32)?.max(1);
    let max_dev = args.usize("devices", 4)?.max(1);
    let batches: Vec<usize> = if batch > 1 { vec![1, batch] } else { vec![1] };
    let mut devices = vec![1usize];
    let mut dv = 2;
    while dv <= max_dev {
        devices.push(dv);
        dv *= 2;
    }
    if *devices.last().unwrap() != max_dev {
        devices.push(max_dev);
    }
    // `--quantize int8` repeats the batched sweep against an int8
    // post-training-quantized bank, gated by `--accept-delta` (max
    // fraction of sentences allowed to differ from the f32 reference).
    let int8_gate = match args.get("quantize") {
        None => None,
        Some("int8") => Some(args.str_or("accept-delta", "0.15").parse::<f64>().with_context(
            || format!("--accept-delta {}", args.str_or("accept-delta", "0.15")),
        )?),
        Some(q) => return Err(anyhow!("--quantize {q}: only `int8` is supported")),
    };
    let out = report::decode_bench(
        &s.engine,
        &s.params,
        &s.bank,
        s.input_feeding,
        &srcs,
        &s.cfg,
        &batches,
        &devices,
        int8_gate,
    )?;
    print!("{out}");
    println!("wrote BENCH_decode.json");
    Ok(())
}

/// Online serving load test: replay one deterministic Poisson arrival
/// schedule through the dynamic micro-batching scheduler at each
/// replica count (1, 2, .., R), verify every response token-identical
/// to the single-sentence reference, and report offered load vs
/// sustained throughput vs tail latency (`BENCH_serve.json` +
/// `results/serve_bench.{txt,csv}`).
fn cmd_serve_load(args: &Args) -> Result<()> {
    let su = serve_setup(args)?;
    let pool_n = args.usize("pool", 32)?.min(su.batcher.test.len());
    if pool_n == 0 {
        return Err(anyhow!("no test sentences survived encoding — larger --sentences?"));
    }
    let pool: Vec<Vec<i32>> = su.batcher.test[..pool_n].iter().map(|e| e.src.clone()).collect();
    let requests = args.usize("requests", 64)?;
    let rate: f64 = args.str_or("rate", "16.0").parse().with_context(|| "--rate")?;
    let seed = args.usize("seed", 0)? as u64;
    let max_rep = args.usize("replicas", 4)?.max(1);
    let mut replica_counts = vec![1usize];
    let mut rv = 2;
    while rv <= max_rep {
        replica_counts.push(rv);
        rv *= 2;
    }
    if *replica_counts.last().unwrap() != max_rep {
        replica_counts.push(max_rep);
    }

    // The correctness gate: the single-sentence reference decode of the
    // pool, compared token-for-token against every served response.
    let decoder = Decoder::new(&su.engine, &su.params, su.input_feeding);
    let reference: Vec<Vec<i32>> = pool
        .iter()
        .map(|src| decoder.translate(src, &su.cfg))
        .collect::<Result<_>>()?;

    let base = ServeOptions {
        replicas: 1,
        queue_capacity: args.usize("queue", 256)?,
        max_wait_ms: args.str_or("max-wait-ms", "5.0").parse().with_context(|| "--max-wait-ms")?,
        bucket_width: args.usize("bucket-width", 4)?,
        panic_replica_at: None,
    };

    let tenants = args.usize("tenants", 1)?;
    if tenants > 1 {
        return serve_load_tenants(args, &su, &pool, &reference, requests, rate, seed, &base);
    }
    // One schedule for every replica count: identical offered load.
    let arrivals = poisson_arrivals(&pool, requests, rate, seed);
    let mut rows = Vec::new();
    for &replicas in &replica_counts {
        let opts = ServeOptions { replicas, ..base };
        let (drive, responses, stats) = run_server(
            &su.engine, &su.params, &su.bank, su.input_feeding, &su.cfg, &opts,
            |h| drive_arrivals(h, &arrivals),
        )?;
        for resp in &responses {
            if resp.tokens != reference[resp.id as usize % pool.len()] {
                return Err(anyhow!(
                    "serving diverged from the single-sentence reference at \
                     request {} ({} replicas)",
                    resp.id,
                    replicas
                ));
            }
        }
        let (p50, p95, p99) = stats.latency_percentiles_ms();
        println!(
            "replicas {replicas}: {}/{} accepted ({} shed) -> {:.2} sent/s sustained, \
             p50/p95/p99 {p50:.1}/{p95:.1}/{p99:.1} ms, fill {:.2}, waste {:.2}, {} stolen groups",
            drive.accepted,
            stats.submitted,
            drive.rejected,
            stats.sentences_per_sec(),
            stats.mean_fill(),
            stats.mean_waste(),
            stats.stolen_groups,
        );
        rows.push(report::ServeRow {
            replicas,
            beam: su.cfg.beam,
            offered_per_s: drive.offered_per_s,
            stats,
        });
    }
    print!("\n{}", report::serve_table(&rows));
    println!("wrote BENCH_serve.json");
    Ok(())
}

/// Multi-tenant serve-load: `--tenants T` tenants under Zipf-skewed
/// popularity share one replica fleet through the deficit-round-robin
/// scheduler. Each tenant also gets a *solo* run of exactly its own
/// slice of the schedule — the fairness baseline its shared-fleet p99
/// is compared against. `--swap-at F` hot-swaps the hottest tenant's
/// model (to an identical parameter clone, so the token-identity gate
/// spans the swap) after fraction F of the arrivals.
#[allow(clippy::too_many_arguments)]
fn serve_load_tenants(
    args: &Args,
    su: &ServeSetup,
    pool: &[Vec<i32>],
    reference: &[Vec<i32>],
    requests: usize,
    rate: f64,
    seed: u64,
    base: &ServeOptions,
) -> Result<()> {
    let n_tenants = args.usize("tenants", 2)?;
    let zipf_s: f64 = args.str_or("zipf-s", "1.0").parse().with_context(|| "--zipf-s")?;
    let users = args.usize("users", 200)? as u64;
    let tenant_queue = args.usize("tenant-queue", 64)?.max(1);
    let swap_at: f64 = args.str_or("swap-at", "0").parse().with_context(|| "--swap-at")?;
    let fairness: f64 =
        args.str_or("fairness-factor", "0").parse().with_context(|| "--fairness-factor")?;
    let replicas = args.usize("replicas", 4)?.max(1);
    let opts = ServeOptions { replicas, ..*base };
    let topts = TenantOpts { queue_cap: tenant_queue, weight: 1 };

    // Hottest-first tenant names (rank 0 of the Zipf sampler).
    let names: Vec<String> = (0..n_tenants).map(|i| format!("t{i}")).collect();
    let arrivals = tenant_arrivals(pool, &names, requests, rate, zipf_s, users, seed);

    let verify = |responses: &[hybridnmt::serve::TenantResponse]| -> Result<()> {
        for r in responses {
            if r.response.tokens != reference[r.response.id as usize % pool.len()] {
                return Err(anyhow!(
                    "tenant `{}` response {} (gen {}) diverged from the single-sentence \
                     reference — a hot-swap mixed or corrupted a group",
                    r.tenant,
                    r.response.id,
                    r.generation
                ));
            }
        }
        Ok(())
    };

    // Solo baselines: each tenant's own slice of the schedule, alone on
    // the same fleet. Its p99 here is what isolation is measured
    // against.
    let mut solo_p99: std::collections::BTreeMap<String, f64> = Default::default();
    for t in &names {
        let slice: Vec<_> = arrivals.iter().filter(|a| &a.tenant == t).cloned().collect();
        if slice.is_empty() {
            continue;
        }
        let registry = TenantRegistry::new();
        registry.attach(t, su.params.clone(), ParamBank::new(), topts)?;
        let (_, responses, _, per_tenant) = run_tenant_server(
            &su.engine, &registry, su.input_feeding, &su.cfg, &opts,
            |h| drive_tenant_arrivals(h, &slice),
        )?;
        verify(&responses)?;
        if let Some(ts) = per_tenant.get(t) {
            solo_p99.insert(t.clone(), ts.latency_pctl_ms(0.99));
        }
    }

    // The shared-fleet run, with the optional mid-run hot-swap.
    let registry = TenantRegistry::new();
    for t in &names {
        registry.attach(t, su.params.clone(), ParamBank::new(), topts)?;
    }
    let split = if swap_at > 0.0 {
        ((requests as f64 * swap_at.clamp(0.0, 1.0)) as usize).min(requests)
    } else {
        0
    };
    let (drive, responses, stats, per_tenant) = run_tenant_server(
        &su.engine, &registry, su.input_feeding, &su.cfg, &opts,
        |h| -> Result<TenantDriveReport> {
            if split == 0 {
                return drive_tenant_arrivals(h, &arrivals);
            }
            let first = drive_tenant_arrivals(h, &arrivals[..split])?;
            let hot = &names[0];
            let old_gen = registry.generation_of(hot).unwrap_or(0);
            let new_gen = registry.swap(hot, su.params.clone(), ParamBank::new())?;
            println!(
                "hot-swap at request {split}: tenant {hot} generation {old_gen} -> {new_gen} \
                 (in-flight work drains on the old generation)"
            );
            let mut rest = drive_tenant_arrivals(h, &arrivals[split..])?;
            rest.accepted += first.accepted;
            rest.rejected += first.rejected;
            rest.unknown += first.unknown;
            for (t, n) in first.shed {
                *rest.shed.entry(t).or_insert(0) += n;
            }
            for (t, n) in first.offered {
                *rest.offered.entry(t).or_insert(0) += n;
            }
            Ok(rest)
        },
    )?;
    verify(&responses)?;
    if responses.len() as u64 != stats.accepted {
        return Err(anyhow!(
            "dropped responses: {} accepted but {} completed",
            stats.accepted,
            responses.len()
        ));
    }
    if split > 0 {
        let hot = &names[0];
        let gens: std::collections::BTreeSet<u64> = responses
            .iter()
            .filter(|r| &r.tenant == hot)
            .map(|r| r.generation)
            .collect();
        println!(
            "tenant {hot} decoded under generations {gens:?}; every response token-identical"
        );
        if !registry.wait_drained(std::time::Duration::from_secs(10)) {
            return Err(anyhow!("old generation failed to drain after the run"));
        }
    }

    let span = arrivals.last().map_or(0.0, |a| a.at_s);
    let mut rows = Vec::new();
    for t in &names {
        let ts = per_tenant.get(t).cloned().unwrap_or_default();
        let offered = *drive.offered.get(t).unwrap_or(&0);
        rows.push(report::TenantRow {
            tenant: t.clone(),
            offered_rps: hybridnmt::util::per_sec(offered as f64, span),
            sustained_rps: hybridnmt::util::per_sec(ts.completed as f64, stats.wall_s),
            p50_ms: ts.latency_pctl_ms(0.50),
            p99_ms: ts.latency_pctl_ms(0.99),
            shed: ts.shed,
            distinct_users_est: ts.distinct_users_est,
            solo_p99_ms: *solo_p99.get(t).unwrap_or(&f64::NAN),
        });
    }
    print!("\n{}", report::tenant_table(&rows));
    println!("wrote BENCH_serve.json (mt.* + prom.* keys) and results/metrics.prom");

    if fairness > 0.0 {
        for r in &rows {
            if r.solo_p99_ms.is_finite() && r.p99_ms > fairness * r.solo_p99_ms {
                return Err(anyhow!(
                    "fairness gate: tenant `{}` p99 {:.1} ms exceeds {fairness} x solo p99 \
                     {:.1} ms",
                    r.tenant,
                    r.p99_ms,
                    r.solo_p99_ms
                ));
            }
        }
        println!("fairness gate passed: every tenant p99 within {fairness}x its solo p99");
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let strategy: Strategy = args.str_or("strategy", "hybrid").parse()?;
    let batch = args.usize("batch", strategy.paper_batch())?;
    let dims = ModelDims::paper().with_batch(batch);
    let hw = HwConfig::default();
    let plan = build_plan(&dims, strategy, hw.dp_host_staged);
    // Optional schedule trace (CSV: step,device,start,end,kind) for
    // timeline inspection — the simulator's flamegraph equivalent.
    if let Some(path) = args.get("trace") {
        let (_, events) = hybridnmt::sim::simulate_traced(&plan, &hw, true);
        let mut csv = String::from("step,device,start,end,kind\n");
        for e in &events {
            csv.push_str(&format!("{},{},{:.9},{:.9},{}\n", e.step, e.device, e.start, e.end, e.kind));
        }
        write_file_atomic(std::path::Path::new(path), csv.as_bytes())?;
        println!("schedule trace ({} events) written to {path}", events.len());
    }
    let sim = simulate(&plan, &hw);
    println!("strategy:       {}", strategy.label());
    println!("plan steps:     {}", plan.steps.len());
    println!("plan GFLOPs:    {:.1}", plan.total_flops() / 1e9);
    println!("comm MB:        {:.1}", plan.comm_bytes() / 1e6);
    println!("sim makespan:   {:.4} s", sim.makespan);
    println!("sync time:      {:.4} s", sim.sync_time);
    println!("transfer busy:  {:.4} s", sim.transfer_time);
    println!("utilization:    {:.1} %", 100.0 * sim.utilization());
    for (d, busy) in sim.device_busy.iter().enumerate() {
        println!("  device {d}: busy {:.4} s ({:.0} %)", busy, 100.0 * busy / sim.makespan);
    }
    Ok(())
}

fn cmd_figure4(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let data = DataConfig::by_name(
        args.str_or("dataset", "wmt14-sim"),
        args.usize("sentences", 3000)?,
    )?;
    let train = TrainConfig {
        steps: args.usize("steps", 200)?,
        eval_interval: args.usize("eval-interval", 20)?,
        decay_interval: args.usize("decay-interval", 100)?,
        ..Default::default()
    };
    let out = report::figure4(&engine, &data, &train, &HwConfig::default(), &Strategy::ALL)?;
    print!("{out}");
    Ok(())
}

fn cmd_table4(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required (train one first)"))?;
    let params = checkpoint::load(std::path::Path::new(ckpt))?;
    let gnmt = args.get("gnmt").is_some();
    let exp = build_experiment(args, &engine)?;
    let corpus = report::make_corpus(&exp.data, &exp.model);
    let batcher = report::make_batcher(&exp, &corpus)?;
    // Input-feeding follows the model the checkpoint was trained with:
    // the GNMT half of Table 4 is the baseline (IF), the Marian half is
    // HybridNMT (no IF).
    let decoder = Decoder::new(&engine, &params, gnmt);
    let beams: Vec<usize> = [3, 6, 9, 12, 15, 18]
        .into_iter()
        .filter(|&b| b <= engine.dims().beam)
        .collect();
    let norms = [1.0, 0.8, 0.6, 0.4, 0.2, 0.0];
    let out = report::table4(&engine, &batcher, &decoder, &corpus, gnmt, &beams, &norms)?;
    print!("{out}");
    Ok(())
}

fn cmd_table5(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let steps = args.usize("steps", 300)?;
    let mut rows: Vec<(String, f64, f64, f64)> = vec![
        ("Luong et al. (2015) [paper ref]".into(), 20.9, f64::NAN, f64::NAN),
        ("GNMT / Wu et al. (2016) [paper ref]".into(), 24.61, f64::NAN, f64::NAN),
    ];
    for (label, strategy) in [
        ("OpenNMT-lua-like baseline (ours)", Strategy::Single),
        ("HybridNMT (ours)", Strategy::Hybrid),
    ] {
        let mut bleus = [0.0f64; 2];
        let (mut dec_sents, mut dec_secs) = (0usize, 0.0f64);
        for (di, ds) in ["wmt14-sim", "wmt17-sim"].iter().enumerate() {
            let mut sub = Args { cmd: "train".into(), flags: args.flags.clone() };
            sub.flags.insert("strategy".into(), strategy.key().into());
            sub.flags.insert("dataset".into(), ds.to_string());
            sub.flags.insert("steps".into(), steps.to_string());
            if strategy == Strategy::Single {
                sub.flags.insert("sgd".into(), "true".into());
            }
            let exp = build_experiment(&sub, &engine)?;
            let corpus = report::make_corpus(&exp.data, &exp.model);
            let mut batcher = report::make_batcher(&exp, &corpus)?;
            let mut trainer = Trainer::new(&engine, &exp)?;
            trainer.run(&mut batcher, |_| {})?;
            // Test decode rides the batched multi-device engine (token-
            // identical to single-sentence decoding); its wall clock
            // feeds the table's decode-throughput column.
            let cfg = BeamConfig {
                beam: 6.min(engine.dims().beam),
                max_len: engine.dims().max_tgt,
                norm: LengthNorm::Marian { alpha: 1.0 },
            };
            let srcs: Vec<Vec<i32>> =
                batcher.test.iter().take(120).map(|e| e.src.clone()).collect();
            let bank = ParamBank::new();
            let opts = DecodeOptions { batch: 32, devices: engine.dims().gpus };
            let (hyps, stats) = translate_corpus(
                &engine,
                trainer.params(),
                &bank,
                strategy.uses_input_feeding(),
                &srcs,
                &cfg,
                &opts,
            )?;
            let pairs: Vec<(String, String)> = batcher
                .test
                .iter()
                .zip(&hyps)
                .map(|(e, hyp)| (batcher.vocab.decode(hyp), batcher.vocab.decode(&e.tgt)))
                .collect();
            bleus[di] = corpus_bleu(&pairs);
            dec_sents += stats.sentences;
            dec_secs += stats.wall_s;
        }
        rows.push((
            label.to_string(),
            bleus[0],
            bleus[1],
            per_sec(dec_sents as f64, dec_secs),
        ));
    }
    print!("{}", report::table5(&rows));
    Ok(())
}
