//! Elastic-recovery chaos suite (requires `make artifacts`).
//!
//! The supervised-world claim on top of `dist_equivalence.rs`: when
//! ranks are killed mid-run — once or repeatedly, over the fake
//! transport or real loopback TCP, in either collective mode — the
//! supervisor detects the failure, relaunches the world, resumes from
//! the newest durable checkpoint, and the recovered run's final
//! parameters are **bitwise-identical** to a fault-free single-process
//! run over the same global shard stream. And when the restart budget
//! runs out, the caller gets a typed error promptly — never a hang.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hybridnmt::config::{
    DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig,
};
use hybridnmt::data::vocab::{BOS, EOS, PAD};
use hybridnmt::dist::{
    run_supervised_world, CommOpts, DistError, DistErrorKind, DistMode, FaultScript, RankSpec,
    ScheduledDeath, SupervisorOpts, WorldKind,
};
use hybridnmt::metrics::Registry;
use hybridnmt::parallel::Batch;
use hybridnmt::rng::Rng;
use hybridnmt::runtime::Engine;
use hybridnmt::storage::{FaultPlan, FaultyMem};
use hybridnmt::tensor::{ITensor, Tensor};
use hybridnmt::train::Trainer;

const BUCKET: usize = 32 * 1024;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

/// Same deterministic batch generator as tests/dist_equivalence.rs —
/// the stream must be identical so the bitwise claim crosses suites.
fn random_batch(d: &ModelDims, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, m, n) = (d.batch, d.max_src, d.max_tgt);
    let mut src = vec![PAD; b * m];
    let mut srclen = vec![0i32; b];
    let mut tgt_in = vec![PAD; b * n];
    let mut tgt_out = vec![PAD; b * n];
    let mut tmask = vec![0.0f32; b * n];
    for bi in 0..b {
        let sl = rng.range(2, m + 1);
        srclen[bi] = sl as i32;
        for t in 0..sl {
            src[bi * m + t] = rng.range(4, d.vocab) as i32;
        }
        let tl = rng.range(1, n);
        tgt_in[bi * n] = BOS;
        for t in 0..tl {
            let tok = rng.range(4, d.vocab) as i32;
            tgt_in[bi * n + t + 1] = tok;
            tgt_out[bi * n + t] = tok;
        }
        tgt_out[bi * n + tl] = EOS;
        for t in 0..=tl {
            tmask[bi * n + t] = 1.0;
        }
    }
    Batch {
        src: ITensor::new(vec![b, m], src),
        srclen: ITensor::new(vec![b], srclen),
        tgt_in: ITensor::new(vec![b, n], tgt_in),
        tgt_out: ITensor::new(vec![b, n], tgt_out),
        tmask: Tensor::new(vec![b, n], tmask),
    }
}

fn test_exp(e: &Engine) -> Experiment {
    Experiment {
        model: e.dims().clone(),
        strategy: Strategy::Hybrid,
        hw: HwConfig::default(),
        train: TrainConfig {
            seed: 3,
            steps: 4,
            eval_interval: 100,
            decay_interval: 2,
            ..Default::default()
        },
        data: DataConfig::wmt14_sim(600),
        artifacts_dir: "artifacts".into(),
    }
}

fn pool(e: &Engine, n: usize) -> Vec<Batch> {
    (0..n).map(|i| random_batch(e.dims(), 9000 + i as u64)).collect()
}

/// Fault-free single-process reference over the same stream.
fn single_process(e: &Engine, pool: &[Batch], steps: usize, shards: usize) -> BTreeMap<String, Tensor> {
    let exp = test_exp(e);
    let mut tr = Trainer::new(e, &exp).unwrap();
    tr.set_bucket_bytes(BUCKET);
    tr.set_pipeline(shards, 1);
    for s in 0..steps {
        tr.train_step_micro(&pool[s * shards..(s + 1) * shards])
            .unwrap_or_else(|err| panic!("reference {shards}-shard step {s}: {err:#}"));
    }
    tr.params().clone()
}

fn dist_spec(e: &Engine, mode: DistMode, steps: usize) -> RankSpec {
    let mut s = RankSpec::new(test_exp(e), mode, 1, 1, steps);
    s.bucket_bytes = Some(BUCKET);
    s
}

fn fresh_store() -> Arc<FaultyMem> {
    Arc::new(FaultyMem::new(FaultPlan::none()))
}

fn assert_params_bitwise(label: &str, a: &BTreeMap<String, Tensor>, b: &BTreeMap<String, Tensor>) {
    assert_eq!(a.len(), b.len(), "{label}: param count");
    for (name, x) in a {
        let y = b.get(name).unwrap_or_else(|| panic!("{label}: missing `{name}`"));
        assert_eq!(x.shape(), y.shape(), "{label}: `{name}` shape");
        for (i, (u, v)) in x.data().iter().zip(y.data()).enumerate() {
            assert!(
                u.to_bits() == v.to_bits(),
                "{label}: `{name}`[{i}] {u} != {v} (bitwise)"
            );
        }
    }
}

// ------------------------------------------------------ soft kills

/// A single soft kill of rank 1 under the fake-transport supervisor:
/// exactly one restart, and every rank of the recovered world lands on
/// the single-process bits. The recovery counters land in the
/// process-wide Prometheus registry.
#[test]
fn fake_ps_soft_kill_recovers_bitwise() {
    let e = engine();
    let procs = 2;
    let steps = 4;
    let p = pool(&e, steps * procs);
    let reference = single_process(&e, &p, steps, procs);
    let mut specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, steps)).collect();
    specs[1].die_script = vec![ScheduledDeath { gen: 0, step: 2, hard: false }];
    let run = run_supervised_world(
        &e,
        &specs,
        WorldKind::Fake,
        &CommOpts::fast(),
        &SupervisorOpts::fast(3),
        fresh_store(),
        1,
        &p,
        vec![FaultScript::clean(); procs],
    )
    .unwrap_or_else(|err| panic!("supervised ps world: {err:#}"));
    assert_eq!(run.recovery.restarts, 1, "one kill, one restart");
    assert_eq!(run.recovery.failures.len(), 1);
    assert!(
        run.recovery.failures[0].1.contains("dist-die"),
        "failure detail should name the kill: {}",
        run.recovery.failures[0].1
    );
    assert_eq!(run.ranks.len(), procs);
    for (r, rank) in run.ranks.iter().enumerate() {
        assert_params_bitwise(&format!("recovered ps rank {r}"), &reference, &rank.params);
    }
    let prom = Registry::global().render();
    for counter in ["dist_supervisor_restarts_total", "dist_supervisor_failures_total"] {
        assert!(prom.contains(counter), "registry must export `{counter}`:\n{prom}");
    }
}

/// Two kills across consecutive incarnations (rank 1 in gen 0, rank 0
/// in gen 1) in replicated mode: two restarts, still bitwise — every
/// incarnation resumes from the durable frontier and replays the same
/// derived stream.
#[test]
fn fake_replicated_repeated_kills_recover_bitwise() {
    let e = engine();
    let procs = 2;
    let steps = 4;
    let p = pool(&e, steps * procs);
    let reference = single_process(&e, &p, steps, procs);
    let mut specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Replicated, steps)).collect();
    specs[1].die_script = vec![ScheduledDeath { gen: 0, step: 2, hard: false }];
    specs[0].die_script = vec![ScheduledDeath { gen: 1, step: 3, hard: false }];
    let run = run_supervised_world(
        &e,
        &specs,
        WorldKind::Fake,
        &CommOpts::fast(),
        &SupervisorOpts::fast(3),
        fresh_store(),
        1,
        &p,
        vec![FaultScript::clean(); procs],
    )
    .unwrap_or_else(|err| panic!("supervised replicated world: {err:#}"));
    assert_eq!(run.recovery.restarts, 2, "two kills, two restarts");
    for (r, rank) in run.ranks.iter().enumerate() {
        assert_params_bitwise(
            &format!("repeated-kill replicated rank {r}"),
            &reference,
            &rank.params,
        );
    }
}

/// The same single-kill drill over real loopback TCP, both collective
/// modes: the relaunch rebinds a fresh rendezvous, resumes from the
/// durable checkpoint, and lands on the reference bits.
#[test]
fn tcp_soft_kill_recovers_bitwise_both_modes() {
    let e = engine();
    let procs = 2;
    let steps = 3;
    for mode in [DistMode::Ps, DistMode::Replicated] {
        let p = pool(&e, steps * procs);
        let reference = single_process(&e, &p, steps, procs);
        let mut specs: Vec<RankSpec> =
            (0..procs).map(|_| dist_spec(&e, mode, steps)).collect();
        specs[1].die_script = vec![ScheduledDeath { gen: 0, step: 2, hard: false }];
        let run = run_supervised_world(
            &e,
            &specs,
            WorldKind::Tcp,
            &CommOpts::fast(),
            &SupervisorOpts::fast(3),
            fresh_store(),
            1,
            &p,
            vec![FaultScript::clean(); procs],
        )
        .unwrap_or_else(|err| panic!("supervised tcp {mode:?} world: {err:#}"));
        assert_eq!(run.recovery.restarts, 1, "{mode:?}: one kill, one restart");
        for (r, rank) in run.ranks.iter().enumerate() {
            assert_params_bitwise(&format!("tcp {mode:?} rank {r}"), &reference, &rank.params);
        }
    }
}

// ------------------------------------------------- poisoned links

/// A rank that drops dead mid-send (no abort courtesy — the fake's
/// `kill_at_send`) poisons its links; the supervisor must still
/// classify the wreck, relaunch on clean transports, and recover to
/// the reference bits.
#[test]
fn poisoned_link_death_recovers_bitwise() {
    let e = engine();
    let procs = 2;
    let steps = 3;
    let p = pool(&e, steps * procs);
    let reference = single_process(&e, &p, steps, procs);
    let specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, steps)).collect();
    let mut scripts = vec![FaultScript::clean(); procs];
    scripts[1].kill_at_send = Some(2);
    let t0 = Instant::now();
    let run = run_supervised_world(
        &e,
        &specs,
        WorldKind::Fake,
        &CommOpts::fast(),
        &SupervisorOpts::fast(3),
        fresh_store(),
        1,
        &p,
        scripts,
    )
    .unwrap_or_else(|err| panic!("supervised poisoned-link world: {err:#}"));
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "mid-send death must be detected and recovered promptly"
    );
    // Transport scripts apply to incarnation 0 only, so exactly one
    // restart suffices.
    assert_eq!(run.recovery.restarts, 1);
    for (r, rank) in run.ranks.iter().enumerate() {
        assert_params_bitwise(&format!("poisoned-link rank {r}"), &reference, &rank.params);
    }
}

// ----------------------------------------------- budget exhaustion

/// A rank that dies in every incarnation exhausts the restart budget:
/// the caller gets a typed Permanent error naming the budget and the
/// last failure — within seconds, never a hang.
#[test]
fn restart_budget_exhaustion_is_typed_and_fast() {
    let e = engine();
    let procs = 2;
    let steps = 3;
    let p = pool(&e, steps * procs);
    let mut specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, steps)).collect();
    // Kill rank 1 before its first step of every incarnation the
    // budget allows (gens 0..=2 for max_restarts = 2).
    specs[1].die_script = (0..3)
        .map(|gen| ScheduledDeath { gen, step: 1, hard: false })
        .collect();
    let t0 = Instant::now();
    let err = run_supervised_world(
        &e,
        &specs,
        WorldKind::Fake,
        &CommOpts::fast(),
        &SupervisorOpts::fast(2),
        fresh_store(),
        1,
        &p,
        vec![FaultScript::clean(); procs],
    )
    .expect_err("a rank dying every incarnation must exhaust the budget");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "budget exhaustion must resolve fast, not hang"
    );
    let d = err
        .downcast_ref::<DistError>()
        .unwrap_or_else(|| panic!("exhaustion must be a typed DistError: {err:#}"));
    assert_eq!(d.kind, DistErrorKind::Permanent);
    assert!(
        d.msg.contains("restart budget exhausted"),
        "error must name the budget: {}",
        d.msg
    );
    assert!(
        d.msg.contains("dist-die"),
        "error must carry the last failure's detail: {}",
        d.msg
    );
}
