//! Kill-mid-write crash recovery: a training run whose checkpoint
//! backend dies (or whose process is killed) mid-write must surface a
//! clean error at the next step boundary, leave `latest` pointing at
//! the last durably-published checkpoint, and resume from it to
//! **bitwise-identical** parameters versus a run that never stopped.
//! Requires `make artifacts` (same gate as `train_equivalence`).

use hybridnmt::config::{
    DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig,
};
use hybridnmt::data::vocab::{BOS, EOS, PAD};
use hybridnmt::parallel::Batch;
use hybridnmt::rng::Rng;
use hybridnmt::runtime::Engine;
use hybridnmt::storage::{FaultPlan, FaultyMem, LocalDir, Retrying, RetryPolicy, Storage};
use hybridnmt::tensor::{ITensor, Tensor};
use hybridnmt::train::checkpoint::{self, checkpoint_key, resolve_latest};
use hybridnmt::train::Trainer;
use std::collections::BTreeMap;
use std::sync::Arc;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

/// A deterministic random batch padded to the artifact shapes (same
/// generator as `train_equivalence`).
fn random_batch(d: &ModelDims, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, m, n) = (d.batch, d.max_src, d.max_tgt);
    let mut src = vec![PAD; b * m];
    let mut srclen = vec![0i32; b];
    let mut tgt_in = vec![PAD; b * n];
    let mut tgt_out = vec![PAD; b * n];
    let mut tmask = vec![0.0f32; b * n];
    for bi in 0..b {
        let sl = rng.range(2, m + 1);
        srclen[bi] = sl as i32;
        for t in 0..sl {
            src[bi * m + t] = rng.range(4, d.vocab) as i32;
        }
        let tl = rng.range(1, n);
        tgt_in[bi * n] = BOS;
        for t in 0..tl {
            let tok = rng.range(4, d.vocab) as i32;
            tgt_in[bi * n + t + 1] = tok;
            tgt_out[bi * n + t] = tok;
        }
        tgt_out[bi * n + tl] = EOS;
        for t in 0..=tl {
            tmask[bi * n + t] = 1.0;
        }
    }
    Batch {
        src: ITensor::new(vec![b, m], src),
        srclen: ITensor::new(vec![b], srclen),
        tgt_in: ITensor::new(vec![b, n], tgt_in),
        tgt_out: ITensor::new(vec![b, n], tgt_out),
        tmask: Tensor::new(vec![b, n], tmask),
    }
}

fn test_exp(e: &Engine) -> Experiment {
    Experiment {
        model: e.dims().clone(),
        strategy: Strategy::Hybrid,
        hw: HwConfig::default(),
        train: TrainConfig { seed: 3, steps: 4, eval_interval: 100, ..Default::default() },
        data: DataConfig::wmt14_sim(600),
        artifacts_dir: "artifacts".into(),
    }
}

fn assert_params_bitwise(label: &str, a: &BTreeMap<String, Tensor>, b: &BTreeMap<String, Tensor>) {
    assert_eq!(a.len(), b.len(), "{label}: param count");
    for (name, x) in a {
        let y = b.get(name).unwrap_or_else(|| panic!("{label}: missing `{name}`"));
        assert_eq!(x.shape(), y.shape(), "{label}: `{name}` shape");
        for (i, (u, v)) in x.data().iter().zip(y.data()).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "{label}: param `{name}`[{i}] {u} vs {v}");
        }
    }
}

/// Train `steps` single-micro steps with no checkpointing — the
/// uninterrupted reference bits.
fn reference_params(e: &Engine, pool: &[Batch], steps: usize) -> BTreeMap<String, Tensor> {
    let exp = test_exp(e);
    let mut tr = Trainer::new(e, &exp).unwrap();
    for b in &pool[..steps] {
        tr.train_step(b).unwrap();
    }
    tr.params().clone()
}

/// The tentpole acceptance test. The backend dies permanently at write
/// attempt #3 — i.e. the step-1 checkpoint (data + `latest` pointer)
/// publishes, then the store goes dark while a later checkpoint is in
/// flight, exactly what a kill mid-write looks like to the protocol.
/// The training thread must see a clean `Err` (at a boundary check or
/// at the final flush — never a panic or hang), `latest` must still
/// resolve to the step-1 checkpoint, and resuming from it must land on
/// the same bits as never crashing. Checkpointing itself must not
/// perturb the numerics: the reference run has no checkpointer at all.
#[test]
fn kill_mid_write_resume_is_bitwise_exact() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let steps = 4;
    let pool: Vec<Batch> = (0..steps).map(|j| random_batch(&d, 900 + j as u64)).collect();
    let reference = reference_params(&e, &pool, steps);

    let store = Arc::new(FaultyMem::new(FaultPlan {
        permanent_from: Some(3),
        ..FaultPlan::none()
    }));
    let mut crashed = Trainer::new(&e, &exp).unwrap();
    crashed.enable_async_checkpoint(store.clone(), 1);
    let mut boundary_err = None;
    for b in &pool {
        crashed.train_step(b).unwrap();
        match crashed.tick_checkpoint() {
            Ok(_) => {}
            Err(err) => {
                boundary_err = Some(err);
                break;
            }
        }
    }
    // The failure surfaces at a step boundary if the writer had already
    // hit the outage, otherwise at the final blocking flush — but it
    // MUST surface, and as an error naming the async writer.
    let err = match boundary_err {
        Some(err) => err,
        None => crashed
            .finalize_checkpoints()
            .expect_err("permanent storage outage must fail the run"),
    };
    assert!(
        format!("{err:#}").contains("async checkpoint writer failed"),
        "unexpected error: {err:#}"
    );
    drop(crashed); // the "kill": joins the writer thread, no more writes

    // The latest pointer never moved past the last durable publish.
    let (key, bytes) =
        resolve_latest(store.as_ref()).unwrap().expect("step-1 checkpoint is durable");
    assert_eq!(key, checkpoint_key(1));
    let ck = checkpoint::load_full_bytes(&bytes).expect("published object is never torn");
    assert_eq!(ck.meta.steps_done, 1);

    // Resume and replay the remaining batches: bitwise the reference.
    let mut resumed = Trainer::new(&e, &exp).unwrap();
    let resumed_key =
        resumed.resume_latest(store.as_ref()).unwrap().expect("latest must resolve");
    assert_eq!(resumed_key, checkpoint_key(1));
    assert_eq!(resumed.steps_done(), 1);
    for b in &pool[1..] {
        resumed.train_step(b).unwrap();
    }
    assert_params_bitwise("resumed-after-kill vs uninterrupted", &reference, resumed.params());
}

/// Transient faults under the retry layer heal without the trainer ever
/// noticing: write #1 fails outright and write #3 tears, both retry to
/// success, the run completes, and `latest` lands on the final
/// checkpoint with clean bytes.
#[test]
fn transient_faults_retry_to_a_clean_final_checkpoint() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let steps = 2;
    let pool: Vec<Batch> = (0..steps).map(|j| random_batch(&d, 950 + j as u64)).collect();
    let reference = reference_params(&e, &pool, steps);

    let store = Arc::new(Retrying::new(
        FaultyMem::new(FaultPlan {
            seed: 5,
            fail_writes: vec![1],
            torn_writes: vec![3],
            ..FaultPlan::none()
        }),
        RetryPolicy::STORAGE,
    ));
    let mut tr = Trainer::new(&e, &exp).unwrap();
    tr.enable_async_checkpoint(store.clone(), 1);
    for b in &pool {
        tr.train_step(b).unwrap();
        tr.tick_checkpoint().unwrap();
    }
    let stats = tr
        .finalize_checkpoints()
        .unwrap()
        .expect("checkpointing was enabled");
    assert!(stats.written >= 1, "final flush must publish: {stats:?}");
    assert_params_bitwise("retried run vs reference", &reference, tr.params());

    let (key, bytes) = resolve_latest(store.as_ref()).unwrap().expect("final checkpoint");
    assert_eq!(key, checkpoint_key(steps as u64));
    let ck = checkpoint::load_full_bytes(&bytes).expect("retried publish is whole");
    assert_eq!(ck.meta.steps_done, steps as u64);
    assert_eq!(ck.params.len(), reference.len());
}

/// The on-disk variant: a killed writer leaves a dotted temp file (and
/// possibly a fully-written data object whose pointer repoint never
/// happened). `resolve_latest` must ignore both, `sweep_temps` reclaims
/// the temp, and resume from the surviving pointer is bitwise-exact.
#[test]
fn local_dir_kill_artifacts_do_not_confuse_resume() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let steps = 4;
    let resumed_from = 2;
    let pool: Vec<Batch> = (0..steps).map(|j| random_batch(&d, 990 + j as u64)).collect();
    let reference = reference_params(&e, &pool, steps);

    let root = std::env::temp_dir()
        .join(format!("hynmt_crash_recovery_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    // Phase 1: train 2 of 4 steps, checkpointing every step, then stop.
    {
        let mut tr = Trainer::new(&e, &exp).unwrap();
        tr.enable_async_checkpoint(Arc::new(LocalDir::new(&root).unwrap()), 1);
        for b in &pool[..resumed_from] {
            tr.train_step(b).unwrap();
            tr.tick_checkpoint().unwrap();
        }
        tr.finalize_checkpoints().unwrap().expect("stats");
    }

    // Phase 2: fake the kill-mid-write debris a crashed step-3 writer
    // would leave behind — a dotted temp never renamed, plus a complete
    // data object whose `latest` repoint never happened.
    std::fs::write(root.join(".ck-00000003.bin.tmp99"), b"torn-mid-write").unwrap();
    let s = LocalDir::new(&root).unwrap();
    s.put_atomic("ck-00000003.bin", b"published-but-never-pointed-at").unwrap();
    assert_eq!(s.sweep_temps().unwrap(), 1, "exactly the one orphan temp");

    // Phase 3: resume must land on the step-2 checkpoint and finish to
    // the reference bits.
    let (key, _) = resolve_latest(&s).unwrap().expect("latest survives the crash");
    assert_eq!(key, checkpoint_key(resumed_from as u64));
    let mut resumed = Trainer::new(&e, &exp).unwrap();
    resumed.resume_latest(&s).unwrap().expect("latest must resolve");
    assert_eq!(resumed.steps_done(), resumed_from);
    for b in &pool[resumed_from..] {
        resumed.train_step(b).unwrap();
    }
    assert_params_bitwise("local-dir resume vs uninterrupted", &reference, resumed.params());

    let _ = std::fs::remove_dir_all(&root);
}
