//! Engine-free unit/property tests for the elastic-training plumbing:
//! the shared backoff policy, heartbeat liveness math, generation
//! filtering on the beacon channel, and the supervision loop's budget
//! arithmetic. No artifacts, no model, no sockets — these must run
//! anywhere `cargo test` runs.

use std::time::{Duration, Instant};

use hybridnmt::dist::supervisor::{from_hex, to_hex};
use hybridnmt::dist::wire::{encode, Frame};
use hybridnmt::dist::{
    supervise, Backoff, DistError, DistErrorKind, FailureCause, HeartbeatMonitor, HeartbeatTx,
    Incarnation, LivenessPolicy, SupervisorOpts,
};

// ------------------------------------------------------------ backoff

/// The unified policy is deterministic in (attempt, u) and capped:
/// delays never exceed `cap_ms` and never go below `base/2` jitter.
#[test]
fn backoff_is_deterministic_capped_and_monotone_in_u() {
    let b = Backoff { max_attempts: 10, base_ms: 20.0, cap_ms: 160.0, seed: 7 };
    for attempt in 0..10 {
        let lo = b.delay_ms(attempt, 0.0);
        let hi = b.delay_ms(attempt, 1.0);
        assert_eq!(lo, b.delay_ms(attempt, 0.0), "deterministic");
        assert!(lo <= hi, "jitter is monotone in u");
        assert!(hi <= 160.0, "attempt {attempt}: {hi} over the cap");
        assert!(lo >= 10.0, "attempt {attempt}: {lo} under base/2");
    }
    // Exponential until the cap bites: 20, 40, 80, 160, 160, ...
    assert_eq!(b.delay_ms(0, 1.0), 20.0);
    assert_eq!(b.delay_ms(1, 1.0), 40.0);
    assert_eq!(b.delay_ms(2, 1.0), 80.0);
    assert_eq!(b.delay_ms(3, 1.0), 160.0);
    assert_eq!(b.delay_ms(9, 1.0), 160.0);
}

#[test]
fn backoff_presets_are_sane() {
    assert!(Backoff::COMM.max_attempts >= 1);
    assert!(Backoff::STORAGE.max_attempts >= 1);
    let i = Backoff::instant(5);
    assert_eq!(i.max_attempts, 5);
    assert_eq!(i.delay_ms(3, 1.0), 0.0, "instant policy never sleeps");
}

// ----------------------------------------------------------- liveness

#[test]
fn liveness_policy_counts_missed_beats() {
    let p = LivenessPolicy::new(50, 4);
    assert_eq!(p.deadline_ms(), 200);
    assert_eq!(p.missed(49), 0);
    assert_eq!(p.missed(50), 1);
    assert_eq!(p.missed(199), 3);
    assert!(!p.is_dead(199));
    assert!(p.is_dead(200));
}

/// Beacon round-trip through the channel sink: what the monitor reads
/// back is the rank/step it was given, silence past the deadline is
/// reported per rank, and a beat resets the clock.
#[test]
fn heartbeat_channel_roundtrip_and_death_detection() {
    let policy = LivenessPolicy::new(10, 2);
    let mut m = HeartbeatMonitor::detached(2, 0, policy);
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    HeartbeatTx::channel(tx.clone(), 0, 0).beat(3);
    HeartbeatTx::channel(tx, 1, 0).beat(7);
    for bytes in rx.try_iter() {
        assert!(m.note_bytes(&bytes, t0).unwrap(), "fresh beats must be accepted");
    }
    assert!(m.has_beaten(0) && m.has_beaten(1));
    assert_eq!(m.max_step(), 7);
    assert!(m.dead_ranks(t0).is_empty(), "fresh beats: nobody dead");
    let late = t0 + Duration::from_millis(policy.deadline_ms() + 1);
    assert_eq!(m.dead_ranks(late), vec![0, 1], "silence kills both");
}

/// Generation filtering: a beacon from a dead incarnation is dropped
/// (counted, not delivered), one from a *future* incarnation is a
/// protocol error — the supervisor must never see time run backwards.
#[test]
fn stale_and_future_generation_beats_are_filtered() {
    let mut m = HeartbeatMonitor::detached(1, 2, LivenessPolicy::new(10, 2));
    let now = Instant::now();
    let beat = |gen: u32, step: u64| encode(&Frame::heartbeat(0, step, gen));
    assert!(!m.note_bytes(&beat(1, 5), now).unwrap(), "stale gen: dropped");
    assert!(m.note_bytes(&beat(2, 6), now).unwrap(), "current gen: delivered");
    let err = m.note_bytes(&beat(3, 7), now).unwrap_err();
    assert_eq!(err.kind, DistErrorKind::Wire, "future gen is a protocol error");
    assert_eq!(m.stale_beats(), 1);
    assert_eq!(m.max_step(), 6, "stale step 5 and future step 7 must not count");
    // Garbage and non-heartbeat frames are typed errors, not panics.
    assert!(m.note_bytes(b"not a frame", now).is_err());
    let oob = encode(&Frame::heartbeat(9, 1, 2));
    assert_eq!(m.note_bytes(&oob, now).unwrap_err().kind, DistErrorKind::Config);
}

#[test]
fn hex_roundtrip_and_rejection() {
    let bytes = vec![0u8, 1, 0xab, 0xff, 42];
    assert_eq!(from_hex(&to_hex(&bytes)).unwrap(), bytes);
    assert!(from_hex("abc").is_none(), "odd length");
    assert!(from_hex("zz").is_none(), "non-hex digits");
    assert_eq!(from_hex("").unwrap(), Vec::<u8>::new());
}

// --------------------------------------------------------- supervise

/// The budget loop: two failing incarnations then success → two
/// restarts, failures recorded per generation, value returned.
#[test]
fn supervise_retries_until_done_and_accounts_failures() {
    let sup = SupervisorOpts::fast(3);
    let (v, stats) = supervise("unit", &sup, |gen| {
        Ok(if gen < 2 {
            Incarnation::Failed {
                cause: FailureCause::RankDied { rank: 1 },
                detail: format!("scripted failure in gen {gen}"),
                lost_steps: 2,
            }
        } else {
            Incarnation::Done(gen)
        })
    })
    .unwrap();
    assert_eq!(v, 2, "succeeded on the third incarnation");
    assert_eq!(stats.restarts, 2);
    assert_eq!(stats.lost_steps, 4);
    assert_eq!(stats.failures.len(), 2);
    assert!(stats.failures[1].1.contains("gen 1"));
}

/// Exhaustion: every incarnation fails → typed Permanent naming the
/// budget and the last failure, promptly (instant backoff).
#[test]
fn supervise_exhaustion_is_typed_permanent_and_fast() {
    let sup = SupervisorOpts::fast(2);
    let t0 = Instant::now();
    let mut launches = 0u32;
    let err = supervise("unit", &sup, |_gen| {
        launches += 1;
        Ok(Incarnation::<()>::Failed {
            cause: FailureCause::HeartbeatTimeout { rank: 0 },
            detail: "silent".into(),
            lost_steps: 0,
        })
    })
    .unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(60), "exhaustion must not hang");
    assert_eq!(launches, 3, "max_restarts 2 = 3 incarnations");
    assert_eq!(err.kind, DistErrorKind::Permanent);
    assert!(err.msg.contains("restart budget exhausted"), "{}", err.msg);
    assert!(err.msg.contains("missed its heartbeat deadline"), "{}", err.msg);
}

/// An `Err` from the launcher (config/environment trouble, not a rank
/// failure) propagates immediately without burning the budget.
#[test]
fn supervise_propagates_launch_errors_without_retrying() {
    let sup = SupervisorOpts::fast(5);
    let mut launches = 0u32;
    let err = supervise("unit", &sup, |_gen| -> Result<Incarnation<()>, DistError> {
        launches += 1;
        Err(DistError::config("bad topology"))
    })
    .unwrap_err();
    assert_eq!(launches, 1, "config errors must not be retried");
    assert_eq!(err.kind, DistErrorKind::Config);
}
