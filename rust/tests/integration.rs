//! End-to-end integration: real PJRT execution of the planned graphs
//! against the `tiny` artifact set (requires `make artifacts`).
//!
//! The load-bearing invariant: `Single`, `Data`, `Model` and `HybridIf`
//! all implement the *same* mathematical model (input-feeding baseline),
//! just scheduled differently — so for identical parameters and batch
//! they must produce identical losses and gradients to float tolerance.
//! That single assertion exercises the whole stack: plan construction,
//! auto-transfers, sharding/scatter/gather, per-step attention, the
//! backward wavefront, and gradient all-reduce.

use hybridnmt::config::{DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig};
use hybridnmt::data::vocab::{BOS, EOS, PAD};
use hybridnmt::decode::{BeamConfig, Decoder, LengthNorm};
use hybridnmt::parallel::{build_plan, execute, Batch};
use hybridnmt::rng::Rng;
use hybridnmt::runtime::Engine;
use hybridnmt::tensor::{ITensor, Tensor};
use hybridnmt::train::{init_params, Trainer};
use std::collections::BTreeMap;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

fn dims(e: &Engine) -> ModelDims {
    e.dims().clone()
}

/// A deterministic random batch padded to the artifact shapes.
fn random_batch(d: &ModelDims, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, m, n) = (d.batch, d.max_src, d.max_tgt);
    let mut src = vec![PAD; b * m];
    let mut srclen = vec![0i32; b];
    let mut tgt_in = vec![PAD; b * n];
    let mut tgt_out = vec![PAD; b * n];
    let mut tmask = vec![0.0f32; b * n];
    for bi in 0..b {
        let sl = rng.range(2, m + 1);
        srclen[bi] = sl as i32;
        for t in 0..sl {
            src[bi * m + t] = rng.range(4, d.vocab) as i32;
        }
        let tl = rng.range(1, n); // + EOS fits in n
        tgt_in[bi * n] = BOS;
        for t in 0..tl {
            let tok = rng.range(4, d.vocab) as i32;
            tgt_in[bi * n + t + 1] = tok;
            tgt_out[bi * n + t] = tok;
        }
        tgt_out[bi * n + tl] = EOS;
        for t in 0..=tl {
            tmask[bi * n + t] = 1.0;
        }
    }
    Batch {
        src: ITensor::new(vec![b, m], src),
        srclen: ITensor::new(vec![b], srclen),
        tgt_in: ITensor::new(vec![b, n], tgt_in),
        tgt_out: ITensor::new(vec![b, n], tgt_out),
        tmask: Tensor::new(vec![b, n], tmask),
    }
}

fn test_exp(e: &Engine, strategy: Strategy) -> Experiment {
    Experiment {
        model: dims(e),
        strategy,
        hw: HwConfig::default(),
        train: TrainConfig { seed: 3, steps: 8, eval_interval: 4, ..Default::default() },
        data: DataConfig::wmt14_sim(600),
        artifacts_dir: "artifacts".into(),
    }
}

fn rel_close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

#[test]
fn input_feeding_strategies_agree_exactly() {
    let e = engine();
    let d = dims(&e);
    let batch = random_batch(&d, 11);
    let exp = test_exp(&e, Strategy::Single);
    let params = init_params(&exp, true);

    let mut results = Vec::new();
    for st in [Strategy::Single, Strategy::Data, Strategy::Model, Strategy::HybridIf] {
        let plan = build_plan(&d, st, true);
        plan.validate().unwrap();
        let out = execute(&plan, &e, &params, &batch)
            .unwrap_or_else(|err| panic!("{st:?}: {err:#}"));
        assert!(out.loss_sum.is_finite(), "{st:?} loss");
        results.push((st, out));
    }
    let (_, base) = &results[0];
    for (st, out) in &results[1..] {
        let rel = (out.loss_sum - base.loss_sum).abs() / base.loss_sum.abs();
        assert!(rel < 1e-4, "{st:?} loss {} vs {}", out.loss_sum, base.loss_sum);
        assert_eq!(out.ntok, base.ntok, "{st:?} ntok");
        assert_eq!(out.grads.len(), base.grads.len(), "{st:?} grad count");
        for (name, g) in &out.grads {
            let bg = &base.grads[name];
            assert!(g.is_finite(), "{st:?} {name} non-finite");
            let (gd, bd) = (g.data(), bg.data());
            let mut worst = 0.0f32;
            for (x, y) in gd.iter().zip(bd) {
                if !rel_close(*x, *y, 2e-3, 2e-4) {
                    worst = worst.max((x - y).abs());
                }
            }
            assert_eq!(worst, 0.0, "{st:?} grad `{name}` max abs diff {worst}");
        }
    }
}

#[test]
fn hybrid_executes_and_differs_from_baseline_model() {
    let e = engine();
    let d = dims(&e);
    let batch = random_batch(&d, 5);
    let exp = test_exp(&e, Strategy::Hybrid);
    // Hybrid uses the no-input-feeding parameter set.
    let params = init_params(&exp, false);
    let plan = build_plan(&d, Strategy::Hybrid, true);
    let out = execute(&plan, &e, &params, &batch).unwrap();
    assert!(out.loss_sum.is_finite() && out.loss_sum > 0.0);
    assert_eq!(out.ntok, batch.target_tokens());
    // Near-uniform init: loss/token ≈ ln V.
    let per_tok = out.loss_sum / out.ntok;
    let lnv = (d.vocab as f64).ln();
    assert!((per_tok - lnv).abs() < 1.0, "per-tok {per_tok} vs ln V {lnv}");
    // Every parameter has a gradient and at least the attention ones are
    // nonzero.
    assert!(out.grads["attn_Wout"].sq_norm() > 0.0);
    assert!(out.grads["src_emb"].sq_norm() > 0.0);
    assert!(out.grads["enc_l0_W"].sq_norm() > 0.0);
}

#[test]
fn gradients_match_finite_difference_on_loss() {
    // Spot-check the full composed gradient against a central finite
    // difference through the executed forward pass (hybrid strategy).
    let e = engine();
    let d = dims(&e);
    let batch = random_batch(&d, 7);
    let exp = test_exp(&e, Strategy::Hybrid);
    let params = init_params(&exp, false);
    let plan = build_plan(&d, Strategy::Hybrid, true);
    let out = execute(&plan, &e, &params, &batch).unwrap();

    let mut rng = Rng::new(99);
    for name in ["attn_Wa", "dec_l0_W", "enc_l1_W", "tgt_emb"] {
        let idx = rng.below(params[name].numel());
        let eps = 2e-2f32;
        let mut plus = params.clone();
        plus.get_mut(name).unwrap().data_mut()[idx] += eps;
        let mut minus = params.clone();
        minus.get_mut(name).unwrap().data_mut()[idx] -= eps;
        let lp = execute(&plan, &e, &plus, &batch).unwrap().loss_sum;
        let lm = execute(&plan, &e, &minus, &batch).unwrap().loss_sum;
        let fd = (lp - lm) / (2.0 * eps as f64);
        let an = out.grads[name].data()[idx] as f64;
        assert!(
            (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
            "{name}[{idx}]: fd {fd} vs analytic {an}"
        );
    }
}

#[test]
fn training_reduces_loss_all_strategies() {
    let e = engine();
    for st in Strategy::ALL {
        let exp = test_exp(&e, st);
        let corpus = hybridnmt::report::make_corpus(&exp.data, &exp.model);
        let mut batcher = hybridnmt::report::make_batcher(&exp, &corpus).unwrap();
        let mut trainer = Trainer::new(&e, &exp).unwrap();
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for i in 0..8 {
            let b = batcher.next_train();
            let stats = trainer.train_step(&b).unwrap();
            assert!(stats.loss_per_tok.is_finite(), "{st:?} step {i}");
            if i == 0 {
                first = stats.loss_per_tok;
            }
            last = stats.loss_per_tok;
        }
        assert!(
            last < first,
            "{st:?}: loss did not decrease ({first} -> {last})"
        );
    }
}

#[test]
fn simulated_clock_orders_strategies_like_table3() {
    // Even at tiny scale, the schedule ordering must hold:
    // hybrid is the fastest multi-GPU strategy.
    let e = engine();
    let d = dims(&e);
    let hw = HwConfig::default();
    let time = |st: Strategy| {
        let plan = build_plan(&d, st, hw.dp_host_staged);
        hybridnmt::sim::simulate(&plan, &hw).makespan
    };
    let hybrid = time(Strategy::Hybrid);
    let hybrid_if = time(Strategy::HybridIf);
    let model = time(Strategy::Model);
    assert!(hybrid < hybrid_if, "hybrid {hybrid} vs IF {hybrid_if}");
    assert!(hybrid < model, "hybrid {hybrid} vs model {model}");
}

#[test]
fn decoder_translates_and_beams_monotone() {
    let e = engine();
    let d = dims(&e);
    let exp = test_exp(&e, Strategy::Hybrid);
    let params = init_params(&exp, false);
    let decoder = Decoder::new(&e, &params, false);
    let src: Vec<i32> = (4..10).collect();
    for beam in [1, 3, d.beam] {
        let cfg = BeamConfig {
            beam,
            max_len: decoder.max_len(),
            norm: LengthNorm::Marian { alpha: 1.0 },
        };
        let out = decoder.translate(&src, &cfg).unwrap();
        assert!(out.len() <= d.max_tgt);
        assert!(out.iter().all(|&t| t != BOS && t != EOS && (t as usize) < d.vocab));
    }
    // GNMT normalization path also runs.
    let cfg = BeamConfig {
        beam: 3,
        max_len: decoder.max_len(),
        norm: LengthNorm::Gnmt { alpha: 1.0, beta: 0.2 },
    };
    decoder.translate(&src, &cfg).unwrap();
}

#[test]
fn manifest_param_counts_match_model_spec() {
    let e = engine();
    let d = dims(&e);
    // aot.py counts the *hybrid* (no-IF) variant.
    let expect = hybridnmt::model_spec::param_count(&d, false);
    assert_eq!(e.manifest.param_count.total, expect);
}

#[test]
fn checkpoint_roundtrip_preserves_training_state() {
    let e = engine();
    let exp = test_exp(&e, Strategy::Hybrid);
    let corpus = hybridnmt::report::make_corpus(&exp.data, &exp.model);
    let mut batcher = hybridnmt::report::make_batcher(&exp, &corpus).unwrap();
    let mut trainer = Trainer::new(&e, &exp).unwrap();
    for _ in 0..3 {
        let b = batcher.next_train();
        trainer.train_step(&b).unwrap();
    }
    let dir = std::env::temp_dir().join("hynmt_int_ck");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.bin");
    hybridnmt::train::checkpoint::save(&path, trainer.params()).unwrap();
    let back = hybridnmt::train::checkpoint::load(&path).unwrap();
    assert_eq!(&back, trainer.params());
    // The reloaded params drive the same forward loss.
    let batch = random_batch(&exp.model, 21);
    let plan = build_plan(&exp.model, Strategy::Hybrid, true);
    let a = execute(&plan, &e, trainer.params(), &batch).unwrap().loss_sum;
    let b = execute(&plan, &e, &back, &batch).unwrap().loss_sum;
    assert_eq!(a, b);
}

#[test]
fn dev_eval_is_deterministic() {
    let e = engine();
    let exp = test_exp(&e, Strategy::Hybrid);
    let corpus = hybridnmt::report::make_corpus(&exp.data, &exp.model);
    let batcher = hybridnmt::report::make_batcher(&exp, &corpus).unwrap();
    let trainer = Trainer::new(&e, &exp).unwrap();
    let dev = batcher.dev_batches();
    assert!(!dev.is_empty());
    let a = trainer.eval_ppl(&dev).unwrap();
    let b = trainer.eval_ppl(&dev).unwrap();
    assert_eq!(a, b);
    assert!(a.is_finite() && a > 1.0);
}

#[test]
fn engine_rejects_bad_shapes() {
    let e = engine();
    let d = dims(&e);
    let bad = Tensor::zeros(&[1, 2]);
    let err = e.exec(
        &hybridnmt::runtime::keys::embed_fwd(d.batch),
        &[hybridnmt::runtime::Arg::F(&bad), hybridnmt::runtime::Arg::F(&bad)],
    );
    assert!(err.is_err());
}

/// Keep a param map clone helper honest (used by finite-difference test).
#[allow(dead_code)]
fn clone_params(p: &BTreeMap<String, Tensor>) -> BTreeMap<String, Tensor> {
    p.clone()
}
