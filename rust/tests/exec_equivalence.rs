//! Executor equivalence: the dependency-driven parallel executor must
//! produce *bitwise-identical* results to the sequential emission-order
//! walk — loss, token count, and every gradient — across all three
//! attention modes and 1/2/4-device placements, with and without the
//! device-resident parameter bank. This is the determinism guarantee
//! `docs/PERF.md` documents: scheduling reorders when steps run, never
//! what they compute (requires `make artifacts`).

use hybridnmt::config::{ModelDims, Strategy};
use hybridnmt::data::vocab::{BOS, EOS, PAD};
use hybridnmt::model_spec::{AttnPlacement, Placement};
use hybridnmt::parallel::replica::build_replica;
use hybridnmt::parallel::{
    build_plan, execute_with, AttnMode, Batch, ExecMode, ExecOptions, Plan, PlanBuilder,
    ReplicaSpec, StepOut,
};
use hybridnmt::rng::Rng;
use hybridnmt::runtime::{Engine, ParamBank};
use hybridnmt::tensor::{ITensor, Tensor};
use hybridnmt::train::init_params;
use std::collections::BTreeMap;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

/// A deterministic random batch padded to the artifact shapes.
fn random_batch(d: &ModelDims, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, m, n) = (d.batch, d.max_src, d.max_tgt);
    let mut src = vec![PAD; b * m];
    let mut srclen = vec![0i32; b];
    let mut tgt_in = vec![PAD; b * n];
    let mut tgt_out = vec![PAD; b * n];
    let mut tmask = vec![0.0f32; b * n];
    for bi in 0..b {
        let sl = rng.range(2, m + 1);
        srclen[bi] = sl as i32;
        for t in 0..sl {
            src[bi * m + t] = rng.range(4, d.vocab) as i32;
        }
        let tl = rng.range(1, n);
        tgt_in[bi * n] = BOS;
        for t in 0..tl {
            let tok = rng.range(4, d.vocab) as i32;
            tgt_in[bi * n + t + 1] = tok;
            tgt_out[bi * n + t] = tok;
        }
        tgt_out[bi * n + tl] = EOS;
        for t in 0..=tl {
            tmask[bi * n + t] = 1.0;
        }
    }
    Batch {
        src: ITensor::new(vec![b, m], src),
        srclen: ITensor::new(vec![b], srclen),
        tgt_in: ITensor::new(vec![b, n], tgt_in),
        tgt_out: ITensor::new(vec![b, n], tgt_out),
        tmask: Tensor::new(vec![b, n], tmask),
    }
}

fn random_params(d: &ModelDims, input_feeding: bool, seed: u64) -> BTreeMap<String, Tensor> {
    let exp = hybridnmt::config::Experiment {
        model: d.clone(),
        strategy: if input_feeding { Strategy::Single } else { Strategy::Hybrid },
        hw: hybridnmt::config::HwConfig::default(),
        train: hybridnmt::config::TrainConfig { seed, ..Default::default() },
        data: hybridnmt::config::DataConfig::wmt14_sim(100),
        artifacts_dir: "artifacts".into(),
    };
    init_params(&exp, input_feeding)
}

/// Bitwise comparison: no tolerance. The two executors run the exact
/// same per-step computations with fixed reduction order, so any
/// difference at all is a scheduling bug.
fn assert_bitwise(label: &str, a: &StepOut, b: &StepOut) {
    assert_eq!(
        a.loss_sum.to_bits(),
        b.loss_sum.to_bits(),
        "{label}: loss {} vs {}",
        a.loss_sum,
        b.loss_sum
    );
    assert_eq!(a.ntok.to_bits(), b.ntok.to_bits(), "{label}: ntok");
    assert_eq!(a.grads.len(), b.grads.len(), "{label}: grad count");
    for (name, g) in &a.grads {
        let h = b.grads.get(name).unwrap_or_else(|| panic!("{label}: missing grad {name}"));
        assert_eq!(g.shape(), h.shape(), "{label}: {name} shape");
        for (i, (x, y)) in g.data().iter().zip(h.data()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: grad `{name}`[{i}] {x} vs {y}"
            );
        }
    }
}

fn run(plan: &Plan, e: &Engine, params: &BTreeMap<String, Tensor>, batch: &Batch, mode: ExecMode, bank: Option<&ParamBank>) -> StepOut {
    execute_with(plan, e, params, batch, &ExecOptions { mode, bank, ..Default::default() })
        .unwrap_or_else(|err| panic!("{mode:?}: {err:#}"))
}

/// All five strategies: covers AttnMode::StepLocal (Single/Data/Model),
/// StepSharded (HybridIf) and BlockSharded (Hybrid), on 1- and
/// 4-device placements, over several random batches.
#[test]
fn parallel_matches_sequential_all_strategies() {
    let e = engine();
    let d = e.dims().clone();
    for st in Strategy::ALL {
        let plan = build_plan(&d, st, true);
        plan.validate().unwrap();
        let params = random_params(&d, st.uses_input_feeding(), 3);
        for seed in [5u64, 11, 23] {
            let batch = random_batch(&d, seed);
            let seq = run(&plan, &e, &params, &batch, ExecMode::Sequential, None);
            let par = run(&plan, &e, &params, &batch, ExecMode::Parallel, None);
            assert_bitwise(&format!("{st:?} seed {seed}"), &seq, &par);
        }
    }
}

/// A 2-device layer split (encoder/decoder stacks straddling a device
/// boundary, attention + state home on device 1) exercises the
/// cross-device transfer edges between the 1- and 4-device extremes.
#[test]
fn parallel_matches_sequential_two_device_placement() {
    let e = engine();
    let d = e.dims().clone();
    let mut b = PlanBuilder::new();
    let placement = Placement {
        emb: 0,
        layer_dev: (0..d.layers).map(|l| usize::from(l >= d.layers / 2)).collect(),
        attn: AttnPlacement::Device(1),
        state_home: 1,
    };
    let spec = ReplicaSpec {
        dims: d.clone(),
        batch: d.batch,
        batch_range: (0, d.batch),
        placement,
        input_feeding: true,
        attn: AttnMode::StepLocal { device: 1 },
    };
    let out = build_replica(&mut b, &spec, d.batch);
    let plan = b.finish(out.grads, out.loss, out.ntok);
    plan.validate().unwrap();
    assert!(
        plan.distinct_devices().iter().filter(|&&dv| dv < 16).count() == 2,
        "placement should span exactly 2 compute devices"
    );
    let params = random_params(&d, true, 7);
    for seed in [2u64, 19] {
        let batch = random_batch(&d, seed);
        let seq = run(&plan, &e, &params, &batch, ExecMode::Sequential, None);
        let par = run(&plan, &e, &params, &batch, ExecMode::Parallel, None);
        assert_bitwise(&format!("2-device seed {seed}"), &seq, &par);
    }
}

/// The device-resident parameter bank must not change numerics: cold
/// (uploading) and warm (fully resident) executions agree bitwise with
/// the bank-less sequential reference, and the bank uploads each
/// parameter exactly once.
#[test]
fn param_bank_preserves_numerics_and_uploads_once() {
    let e = engine();
    let d = e.dims().clone();
    let plan = build_plan(&d, Strategy::Hybrid, true);
    let params = random_params(&d, false, 13);
    let batch = random_batch(&d, 17);

    let reference = run(&plan, &e, &params, &batch, ExecMode::Sequential, None);
    let bank = ParamBank::new();
    let cold = run(&plan, &e, &params, &batch, ExecMode::Parallel, Some(&bank));
    assert_eq!(bank.upload_count() as usize, params.len(), "one upload per parameter");
    let warm = run(&plan, &e, &params, &batch, ExecMode::Parallel, Some(&bank));
    assert_eq!(bank.upload_count() as usize, params.len(), "warm run re-uploaded");
    assert!(bank.hit_count() > 0, "warm run should hit the bank");
    assert_bitwise("bank cold", &reference, &cold);
    assert_bitwise("bank warm", &reference, &warm);

    // Invalidation forces a fresh upload set (stale-buffer protection).
    bank.invalidate();
    let after = run(&plan, &e, &params, &batch, ExecMode::Parallel, Some(&bank));
    assert_eq!(bank.upload_count() as usize, 2 * params.len());
    assert_bitwise("bank after invalidate", &reference, &after);
}
