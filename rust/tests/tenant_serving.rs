//! Multi-tenant serving acceptance: hot-swap under live load never
//! drops or mixes responses (and releases the old generation's buffers
//! only after its in-flight work drains), per-tenant admission caps
//! shed only the offending tenant, detach drains cleanly, and the
//! per-tenant bench rows + Prometheus dump land on disk (requires
//! `make artifacts`).
//!
//! The engine-free scheduler properties (DRR fairness, HLL accuracy,
//! Zipf exactness, generation/pin bookkeeping) live in
//! `tests/property.rs` and the unit tests; this file is where a real
//! decode pipeline runs behind the registry.

use hybridnmt::config::{DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig};
use hybridnmt::decode::{BeamConfig, Decoder, LengthNorm};
use hybridnmt::report::{tenant_table, TenantRow};
use hybridnmt::rng::Rng;
use hybridnmt::runtime::{quantize_params, Engine, ParamBank};
use hybridnmt::serve::{
    drive_tenant_arrivals, run_tenant_server, tenant_arrivals, ServeOptions, SubmitError,
    TenantOpts, TenantRegistry,
};
use hybridnmt::tensor::Tensor;
use hybridnmt::train::init_params;
use hybridnmt::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

fn random_params(d: &ModelDims, seed: u64) -> BTreeMap<String, Tensor> {
    let exp = Experiment {
        model: d.clone(),
        strategy: Strategy::Hybrid,
        hw: HwConfig::default(),
        train: TrainConfig { seed, ..Default::default() },
        data: DataConfig::wmt14_sim(100),
        artifacts_dir: "artifacts".into(),
    };
    init_params(&exp, false)
}

fn random_srcs(d: &ModelDims, n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = rng.range(2, d.max_src + 1);
            (0..len).map(|_| rng.range(4, d.vocab) as i32).collect()
        })
        .collect()
}

fn cfg(beam: usize, max_tgt: usize) -> BeamConfig {
    BeamConfig { beam, max_len: max_tgt, norm: LengthNorm::Marian { alpha: 1.0 } }
}

fn registry_with(params: &BTreeMap<String, Tensor>, tenants: &[(&str, TenantOpts)]) -> TenantRegistry {
    let r = TenantRegistry::new();
    for (id, opts) in tenants {
        r.attach(id, params.clone(), ParamBank::new(), *opts).unwrap();
    }
    r
}

/// The headline acceptance test: a hot-swap lands while requests are in
/// flight. Every admitted request completes with reference-identical
/// tokens; requests admitted before the swap decode under the old
/// generation, requests admitted after under the new one — never a
/// mixed group — and the old generation's buffers are released only
/// after its last in-flight request drains.
#[test]
fn hot_swap_under_load_never_drops_or_mixes() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 3);
    let pool = random_srcs(&d, 8, 42);
    let c = cfg(4, d.max_tgt);
    let dec = Decoder::new(&e, &params, false);
    let reference: Vec<Vec<i32>> = pool.iter().map(|s| dec.translate(s, &c).unwrap()).collect();

    let reg = registry_with(&params, &[("alpha", TenantOpts::default()), ("beta", TenantOpts::default())]);
    let gen1 = reg.generation_of("alpha").unwrap();
    // Generous max_wait so pre- and post-swap submissions would land in
    // one group if the coalescer ignored generations.
    let opts = ServeOptions { replicas: 2, queue_capacity: 64, max_wait_ms: 50.0, ..Default::default() };
    let (swap_info, responses, stats, per_tenant) =
        run_tenant_server(&e, &reg, false, &c, &opts, |h| {
            // Phase 1: load both tenants, then swap alpha while that
            // work is (at least partly) still in flight.
            for i in 0..8u64 {
                h.submit("alpha", i, 100 + i, pool[i as usize % pool.len()].clone()).unwrap();
                h.submit("beta", 100 + i, 200 + i, pool[(100 + i) as usize % pool.len()].clone())
                    .unwrap();
            }
            let probe = reg.pin("alpha").unwrap().model().release_probe();
            let gen2 = reg.swap("alpha", params.clone(), ParamBank::new()).unwrap();
            // Phase 2: post-swap traffic pins the new generation.
            for i in 8..16u64 {
                h.submit("alpha", i, 100 + i, pool[i as usize % pool.len()].clone()).unwrap();
            }
            Ok((gen2, probe))
        })
        .unwrap();
    let (gen2, probe) = swap_info;
    assert!(gen2 > gen1);

    // Never drops: every admitted request completed.
    assert_eq!(responses.len() as u64, stats.accepted);
    assert_eq!(per_tenant["alpha"].completed, 16);
    assert_eq!(per_tenant["beta"].completed, 8);
    // Never mixes: the generation a request decodes under is exactly
    // the one current at its admission.
    for r in &responses {
        assert_eq!(
            r.response.tokens,
            reference[r.response.id as usize % pool.len()],
            "tenant {} request {} (gen {}) diverged across the swap",
            r.tenant,
            r.response.id,
            r.generation
        );
        if r.tenant == "alpha" {
            let expect = if r.response.id < 8 { gen1 } else { gen2 };
            assert_eq!(
                r.generation, expect,
                "request {} decoded under generation {}, admitted under {}",
                r.response.id, r.generation, expect
            );
        }
    }
    // The old generation has fully drained by the time run_tenant_server
    // returns (it never returns with work in flight), so its buffers —
    // watched by the probe — must now be released.
    assert!(reg.wait_drained(Duration::from_secs(5)), "old generation must drain");
    assert!(probe.load(Ordering::SeqCst), "old generation buffers released after drain");
}

/// A *precision* hot-swap: alpha's weights are re-published behind an
/// int8 quantized bank while f32 work is still in flight. The weights
/// are snapped onto the int8 grid with a power-of-two scale first, so
/// the quantized decode is token-identical to f32 — any coalescer
/// group that mixed the two precisions, or a request decoded under the
/// wrong generation's bank, would surface as a divergent token
/// sequence or a wrong pinned generation. (The coalescer keys groups
/// on (tenant, generation, quant), so f32 and int8 traffic can never
/// share a device batch even with a generous coalescing window.)
#[test]
fn quantized_hot_swap_never_mixes_precisions() {
    let e = engine();
    let d = e.dims().clone();
    let raw = random_params(&d, 11);
    // Quantize → dequantize is the identity on these weights (2^-10
    // scale), so one reference covers both generations.
    let params: BTreeMap<String, Tensor> = {
        let q0 = quantize_params(&raw);
        raw.keys()
            .map(|k| {
                let qt = q0.get(k).unwrap();
                let data: Vec<f32> =
                    qt.data.iter().map(|&v| v as f32 * 0.0009765625).collect();
                (k.clone(), Tensor::new(qt.shape.clone(), data))
            })
            .collect()
    };
    let pool = random_srcs(&d, 8, 13);
    let c = cfg(4, d.max_tgt);
    let dec = Decoder::new(&e, &params, false);
    let reference: Vec<Vec<i32>> = pool.iter().map(|s| dec.translate(s, &c).unwrap()).collect();

    let reg = registry_with(&params, &[("alpha", TenantOpts::default())]);
    let gen1 = reg.generation_of("alpha").unwrap();
    let opts = ServeOptions {
        replicas: 2,
        queue_capacity: 64,
        max_wait_ms: 50.0,
        ..Default::default()
    };
    let (gen2, responses, stats, per_tenant) =
        run_tenant_server(&e, &reg, false, &c, &opts, |h| {
            // Phase 1: f32 traffic, then swap in the quantized bank
            // while it is (at least partly) still in flight.
            for i in 0..8u64 {
                h.submit("alpha", i, 100 + i, pool[i as usize % pool.len()].clone()).unwrap();
            }
            let qbank = ParamBank::new();
            qbank.set_quantized(std::sync::Arc::new(quantize_params(&params)));
            assert_eq!(qbank.quant_kind(), Some("int8"));
            let gen2 = reg.swap("alpha", params.clone(), qbank).unwrap();
            // Phase 2: post-swap traffic decodes through int8 binds.
            for i in 8..16u64 {
                h.submit("alpha", i, 100 + i, pool[i as usize % pool.len()].clone()).unwrap();
            }
            Ok(gen2)
        })
        .unwrap();
    assert!(gen2 > gen1);

    assert_eq!(responses.len() as u64, stats.accepted);
    assert_eq!(per_tenant["alpha"].completed, 16);
    for r in &responses {
        assert_eq!(
            r.response.tokens,
            reference[r.response.id as usize % pool.len()],
            "request {} (gen {}) diverged across the precision swap",
            r.response.id,
            r.generation
        );
        let expect = if r.response.id < 8 { gen1 } else { gen2 };
        assert_eq!(
            r.generation, expect,
            "request {} decoded under generation {}, admitted under {}",
            r.response.id, r.generation, expect
        );
    }
    assert!(reg.wait_drained(Duration::from_secs(5)), "old f32 generation must drain");
}

/// Per-tenant admission caps: a burst from one tenant over its own cap
/// sheds with `TenantOverQueue` naming that tenant, while another
/// tenant's traffic is admitted untouched — the isolation boundary.
#[test]
fn tenant_cap_sheds_only_the_hot_tenant() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 5);
    let pool = random_srcs(&d, 6, 7);
    let c = cfg(4, d.max_tgt);
    let reg = registry_with(
        &params,
        &[
            ("hot", TenantOpts { queue_cap: 2, weight: 1 }),
            ("cold", TenantOpts { queue_cap: 64, weight: 1 }),
        ],
    );
    let opts = ServeOptions { replicas: 1, queue_capacity: 256, ..Default::default() };
    let (shed, responses, stats, per_tenant) =
        run_tenant_server(&e, &reg, false, &c, &opts, |h| {
            let mut shed = 0u64;
            for i in 0..24u64 {
                match h.submit("hot", i, i, pool[i as usize % pool.len()].clone()) {
                    Ok(()) => {}
                    Err(SubmitError::TenantOverQueue { tenant, capacity }) => {
                        assert_eq!(tenant, "hot");
                        assert_eq!(capacity, 2);
                        shed += 1;
                    }
                    Err(other) => panic!("unexpected submit error: {other}"),
                }
            }
            for i in 0..6u64 {
                h.submit("cold", 100 + i, 100 + i, pool[i as usize % pool.len()].clone())
                    .expect("cold tenant must be unaffected by hot's sheds");
            }
            // And an unattached tenant is a typed refusal, not a panic.
            assert!(matches!(
                h.submit("nope", 999, 0, pool[0].clone()),
                Err(SubmitError::UnknownTenant { .. })
            ));
            Ok(shed)
        })
        .unwrap();
    assert!(shed > 0, "24-burst against a cap of 2 must shed");
    assert_eq!(per_tenant["hot"].shed, shed);
    assert_eq!(per_tenant["cold"].shed, 0);
    assert_eq!(stats.rejected, 0, "tenant sheds are not global QueueFull rejections");
    assert_eq!(responses.len() as u64, stats.accepted, "every admitted request completes");
    assert_eq!(per_tenant["cold"].completed, 6);
    // Distinct-user estimates: small cardinalities are near-exact.
    assert!((per_tenant["cold"].distinct_users_est - 6.0).abs() <= 1.0);
}

/// Detach while requests are in flight: the tenant disappears from
/// routing immediately (subsequent submissions get `UnknownTenant`),
/// already-admitted work completes with correct tokens, and the
/// detached generation drains and releases.
#[test]
fn detach_while_in_flight_drains_cleanly() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 9);
    let pool = random_srcs(&d, 6, 13);
    let c = cfg(4, d.max_tgt);
    let dec = Decoder::new(&e, &params, false);
    let reference: Vec<Vec<i32>> = pool.iter().map(|s| dec.translate(s, &c).unwrap()).collect();
    let reg = registry_with(&params, &[("gone", TenantOpts::default()), ("stay", TenantOpts::default())]);
    let opts = ServeOptions { replicas: 2, queue_capacity: 64, ..Default::default() };
    let (probe, responses, stats, per_tenant) =
        run_tenant_server(&e, &reg, false, &c, &opts, |h| {
            for i in 0..6u64 {
                h.submit("gone", i, i, pool[i as usize % pool.len()].clone()).unwrap();
                h.submit("stay", 100 + i, i, pool[(100 + i) as usize % pool.len()].clone())
                    .unwrap();
            }
            let probe = reg.pin("gone").unwrap().model().release_probe();
            reg.detach("gone").unwrap();
            assert!(matches!(
                h.submit("gone", 50, 0, pool[0].clone()),
                Err(SubmitError::UnknownTenant { .. })
            ));
            Ok(probe)
        })
        .unwrap();
    assert_eq!(responses.len() as u64, stats.accepted);
    assert_eq!(per_tenant["gone"].completed, 6, "in-flight work survives the detach");
    assert_eq!(per_tenant["stay"].completed, 6);
    for r in &responses {
        assert_eq!(r.response.tokens, reference[r.response.id as usize % pool.len()]);
    }
    assert!(reg.wait_drained(Duration::from_secs(5)));
    assert_eq!(reg.tenants(), vec!["stay".to_string()]);
    assert!(probe.load(Ordering::SeqCst), "detached generation released after drain");
}

/// The per-tenant bench artifact: `tenant_table` writes `mt.{tenant}.*`
/// rows (the schema `scripts/verify.sh` enforces) into
/// `BENCH_serve.json`, plus the Prometheus dump at
/// `results/metrics.prom` with the serve/coalesce/loadgen counter
/// families and the HLL-backed distinct-user gauge.
#[test]
fn tenant_bench_rows_and_prometheus_dump() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 17);
    let pool = random_srcs(&d, 6, 19);
    let c = cfg(4, d.max_tgt);
    let reg = registry_with(&params, &[("ten-a", TenantOpts::default()), ("ten-b", TenantOpts::default())]);
    let opts = ServeOptions { replicas: 2, queue_capacity: 64, ..Default::default() };
    let names = vec!["ten-a".to_string(), "ten-b".to_string()];
    let schedule = tenant_arrivals(&pool, &names, 16, 200.0, 1.0, 8, 77);
    let (report, _, stats, per_tenant) = run_tenant_server(&e, &reg, false, &c, &opts, |h| {
        drive_tenant_arrivals(h, &schedule)
    })
    .unwrap();
    assert_eq!(report.accepted, stats.accepted);
    let rows: Vec<TenantRow> = per_tenant
        .iter()
        .map(|(t, ts)| TenantRow {
            tenant: t.clone(),
            offered_rps: ts.submitted as f64,
            sustained_rps: ts.completed as f64 / stats.wall_s.max(1e-9),
            p50_ms: ts.latency_pctl_ms(0.50),
            p99_ms: ts.latency_pctl_ms(0.99),
            shed: ts.shed,
            distinct_users_est: ts.distinct_users_est,
            solo_p99_ms: f64::NAN,
        })
        .collect();
    let out = tenant_table(&rows);
    assert!(out.contains("ten-a") && out.contains("p99"));

    let text = std::fs::read_to_string("BENCH_serve.json").unwrap();
    let obj = Json::parse(&text).unwrap().as_obj().cloned().unwrap();
    assert!(per_tenant.contains_key("ten-a"), "the hot Zipf rank must see traffic");
    for t in per_tenant.keys() {
        for suffix in ["offered_rps", "sustained_rps", "p99_ms", "shed", "distinct_users_est"] {
            let key = format!("mt.{t}.{suffix}");
            assert!(
                obj.get(&key).and_then(|v| v.as_f64()).is_some_and(f64::is_finite),
                "BENCH_serve.json missing finite `{key}`"
            );
        }
    }
    assert!(
        obj.keys().any(|k| k.starts_with("prom.")),
        "registry totals must be snapshotted as prom.* keys"
    );

    let prom = std::fs::read_to_string("results/metrics.prom").unwrap();
    for family in [
        "serve_submitted_total",
        "serve_latency_ms",
        "loadgen_offered_total",
        "serve_distinct_users",
    ] {
        assert!(prom.contains(&format!("# TYPE {family}")), "metrics.prom missing {family}");
    }
    // Histogram exposition shape: cumulative buckets ending at +Inf.
    assert!(prom.contains("le=\"+Inf\""));
}
