//! Distributed training equivalence and fault-injection suite
//! (requires `make artifacts`).
//!
//! The headline claim of the dist subsystem: a world of P processes ×
//! L local shards per step produces **bitwise-identical** parameters
//! to the single-process flat engine consuming the same P·L shards —
//! for both collective modes (rank-0 parameter server and the
//! hierarchical tree+ring all-reduce), over both the in-memory fake
//! transport and real loopback TCP. The reduction-tree factorization
//! that makes this hold is argued in `dist::mod` and
//! docs/ARCHITECTURE.md; this suite is the gate.
//!
//! The second claim: every injected fault — a killed rank, a torn
//! frame, a transient drop, a permanent outage — surfaces on every
//! surviving rank as a *typed* error at a step boundary, bounded by
//! the read timeout. No hang, no panic, no silent divergence.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use hybridnmt::config::{
    DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig,
};
use hybridnmt::data::vocab::{BOS, EOS, PAD};
use hybridnmt::dist::{
    run_fake_world, run_tcp_world, CommOpts, DistError, DistMode, FaultScript, RankSpec,
};
use hybridnmt::parallel::Batch;
use hybridnmt::rng::Rng;
use hybridnmt::runtime::Engine;
use hybridnmt::tensor::{ITensor, Tensor};
use hybridnmt::train::Trainer;

/// Small bucket size so even the tiny model crosses several Grad/Param
/// frames per step (exercises the multi-bucket wire path). Bucket
/// boundaries are elementwise-neutral, so this cannot change numerics.
const BUCKET: usize = 32 * 1024;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

/// A deterministic random batch padded to the artifact shapes (same
/// generator as tests/train_equivalence.rs).
fn random_batch(d: &ModelDims, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, m, n) = (d.batch, d.max_src, d.max_tgt);
    let mut src = vec![PAD; b * m];
    let mut srclen = vec![0i32; b];
    let mut tgt_in = vec![PAD; b * n];
    let mut tgt_out = vec![PAD; b * n];
    let mut tmask = vec![0.0f32; b * n];
    for bi in 0..b {
        let sl = rng.range(2, m + 1);
        srclen[bi] = sl as i32;
        for t in 0..sl {
            src[bi * m + t] = rng.range(4, d.vocab) as i32;
        }
        let tl = rng.range(1, n);
        tgt_in[bi * n] = BOS;
        for t in 0..tl {
            let tok = rng.range(4, d.vocab) as i32;
            tgt_in[bi * n + t + 1] = tok;
            tgt_out[bi * n + t] = tok;
        }
        tgt_out[bi * n + tl] = EOS;
        for t in 0..=tl {
            tmask[bi * n + t] = 1.0;
        }
    }
    Batch {
        src: ITensor::new(vec![b, m], src),
        srclen: ITensor::new(vec![b], srclen),
        tgt_in: ITensor::new(vec![b, n], tgt_in),
        tgt_out: ITensor::new(vec![b, n], tgt_out),
        tmask: Tensor::new(vec![b, n], tmask),
    }
}

fn test_exp(e: &Engine) -> Experiment {
    Experiment {
        model: e.dims().clone(),
        strategy: Strategy::Hybrid,
        hw: HwConfig::default(),
        train: TrainConfig {
            seed: 3,
            steps: 4,
            eval_interval: 100,
            decay_interval: 2,
            ..Default::default()
        },
        data: DataConfig::wmt14_sim(600),
        artifacts_dir: "artifacts".into(),
    }
}

fn pool(e: &Engine, n: usize) -> Vec<Batch> {
    (0..n).map(|i| random_batch(e.dims(), 9000 + i as u64)).collect()
}

/// Single-process flat-engine reference: `shards` micro-batches per
/// optimizer step, consumed in pool order.
fn single_process(e: &Engine, pool: &[Batch], steps: usize, shards: usize) -> BTreeMap<String, Tensor> {
    let exp = test_exp(e);
    let mut tr = Trainer::new(e, &exp).unwrap();
    tr.set_bucket_bytes(BUCKET);
    tr.set_pipeline(shards, 1);
    for s in 0..steps {
        tr.train_step_micro(&pool[s * shards..(s + 1) * shards])
            .unwrap_or_else(|err| panic!("reference {shards}-shard step {s}: {err:#}"));
    }
    tr.params().clone()
}

fn dist_spec(e: &Engine, mode: DistMode, replicas: usize, steps: usize) -> RankSpec {
    let mut s = RankSpec::new(test_exp(e), mode, replicas, 1, steps);
    s.bucket_bytes = Some(BUCKET);
    s
}

fn assert_params_bitwise(label: &str, a: &BTreeMap<String, Tensor>, b: &BTreeMap<String, Tensor>) {
    assert_eq!(a.len(), b.len(), "{label}: param count");
    for (name, x) in a {
        let y = b.get(name).unwrap_or_else(|| panic!("{label}: missing `{name}`"));
        assert_eq!(x.shape(), y.shape(), "{label}: `{name}` shape");
        for (i, (u, v)) in x.data().iter().zip(y.data()).enumerate() {
            assert!(
                u.to_bits() == v.to_bits(),
                "{label}: `{name}`[{i}] {u} != {v} (bitwise)"
            );
        }
    }
}

fn expect_typed(label: &str, res: &anyhow::Result<hybridnmt::dist::RankRun>) -> String {
    let err = match res {
        Ok(_) => panic!("{label}: expected a typed error, rank succeeded"),
        Err(e) => e,
    };
    err.downcast_ref::<DistError>()
        .unwrap_or_else(|| panic!("{label}: error is not a DistError: {err:#}"));
    format!("{err:#}")
}

// ----------------------------------------------------- equivalence

/// procs {1,2,4} × modes {ps,replicated} × replicas-per-proc {1,2} on
/// the in-memory fake transport: every rank's final params bitwise
/// equal to the single-process run over the same global shard stream.
#[test]
fn fake_worlds_match_single_process_bitwise() {
    let e = engine();
    let steps = 2;
    for procs in [1usize, 2, 4] {
        for rpp in [1usize, 2] {
            let shards = procs * rpp;
            let p = pool(&e, steps * shards);
            let reference = single_process(&e, &p, steps, shards);
            for mode in [DistMode::Ps, DistMode::Replicated] {
                let specs: Vec<RankSpec> =
                    (0..procs).map(|_| dist_spec(&e, mode, rpp, steps)).collect();
                let runs =
                    run_fake_world(&e, &specs, vec![FaultScript::clean(); procs], CommOpts::fast(), &p);
                for (r, run) in runs.into_iter().enumerate() {
                    let label = format!("fake {procs}p x {rpp}rep {mode:?} rank {r}");
                    let run = run.unwrap_or_else(|err| panic!("{label}: {err:#}"));
                    assert_params_bitwise(&label, &reference, &run.params);
                }
            }
        }
    }
}

/// Same bitwise claim over real loopback TCP (full rendezvous + wire
/// protocol): procs {1,2,4} at 1 replica/proc in both modes, plus the
/// 2-proc × 2-replica corner.
#[test]
fn tcp_worlds_match_single_process_bitwise() {
    let e = engine();
    let steps = 2;
    for (procs, rpp) in [(1usize, 1usize), (2, 1), (4, 1), (2, 2)] {
        let shards = procs * rpp;
        let p = pool(&e, steps * shards);
        let reference = single_process(&e, &p, steps, shards);
        for mode in [DistMode::Ps, DistMode::Replicated] {
            let specs: Vec<RankSpec> =
                (0..procs).map(|_| dist_spec(&e, mode, rpp, steps)).collect();
            let runs = run_tcp_world(&e, &specs, CommOpts::fast(), &p);
            for (r, run) in runs.into_iter().enumerate() {
                let label = format!("tcp {procs}p x {rpp}rep {mode:?} rank {r}");
                let run = run.unwrap_or_else(|err| panic!("{label}: {err:#}"));
                assert_params_bitwise(&label, &reference, &run.params);
            }
        }
    }
}

/// A non-power-of-two local shard count breaks the reduction-tree
/// factorization and must be rejected up front, not silently diverge.
#[test]
fn non_pow2_local_shards_rejected() {
    let e = engine();
    let steps = 1;
    let procs = 2;
    let rpp = 3; // 3 local shards: not a power of two
    let p = pool(&e, steps * procs * rpp);
    let specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, rpp, steps)).collect();
    let runs = run_fake_world(&e, &specs, vec![FaultScript::clean(); procs], CommOpts::fast(), &p);
    for (r, run) in runs.iter().enumerate() {
        let msg = expect_typed(&format!("non-po2 rank {r}"), run);
        assert!(msg.contains("power-of-two"), "rank {r}: {msg}");
    }
}

// -------------------------------------------------- fault injection

/// A rank that dies mid-run (soft kill just before its step) surfaces
/// as a typed error on EVERY rank — the killed one names the kill, the
/// survivors get abort/timeout errors — within the fast timeouts, in
/// both collective modes.
#[test]
fn killed_rank_yields_typed_errors_everywhere() {
    let e = engine();
    let procs = 3;
    let steps = 3;
    for mode in [DistMode::Ps, DistMode::Replicated] {
        let p = pool(&e, steps * procs);
        let mut specs: Vec<RankSpec> =
            (0..procs).map(|_| dist_spec(&e, mode, 1, steps)).collect();
        specs[1].die_at_step = Some(2);
        let t0 = Instant::now();
        let runs =
            run_fake_world(&e, &specs, vec![FaultScript::clean(); procs], CommOpts::fast(), &p);
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "{mode:?}: world must fail fast, not hang"
        );
        for (r, run) in runs.iter().enumerate() {
            let msg = expect_typed(&format!("{mode:?} kill rank {r}"), run);
            if r == 1 {
                assert!(msg.contains("dist-die"), "killed rank should name the kill: {msg}");
            }
        }
    }
}

/// Same kill drill over real loopback TCP: the survivor's error comes
/// from the abort frame / read timeout, never a hang.
#[test]
fn tcp_killed_rank_yields_typed_error_on_survivor() {
    let e = engine();
    let procs = 2;
    let steps = 2;
    let p = pool(&e, steps * procs);
    let mut specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, 1, steps)).collect();
    specs[1].die_at_step = Some(1);
    let t0 = Instant::now();
    let runs = run_tcp_world(&e, &specs, CommOpts::fast(), &p);
    assert!(t0.elapsed() < Duration::from_secs(60), "tcp kill must fail fast");
    for (r, run) in runs.iter().enumerate() {
        expect_typed(&format!("tcp kill rank {r}"), run);
    }
}

/// A scripted transient drop is retried by the sender's capped backoff
/// and the step completes **bitwise-correct** — faults the retry layer
/// absorbs are invisible to the numerics.
#[test]
fn transient_drop_retries_to_bitwise_correct_step() {
    let e = engine();
    let procs = 2;
    let steps = 2;
    let p = pool(&e, steps * procs);
    let reference = single_process(&e, &p, steps, procs);
    let specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, 1, steps)).collect();
    let mut scripts = vec![FaultScript::clean(); procs];
    // Rank 1's first and third send attempts are dropped in flight.
    scripts[1].fail_sends = vec![1, 3];
    let runs = run_fake_world(&e, &specs, scripts, CommOpts::fast(), &p);
    for (r, run) in runs.into_iter().enumerate() {
        let label = format!("transient-drop rank {r}");
        let run = run.unwrap_or_else(|err| panic!("{label}: {err:#}"));
        assert_params_bitwise(&label, &reference, &run.params);
    }
}

/// A torn frame (peer died mid-write) decodes to a typed error on the
/// receiver; the sender is told via the abort path. Nobody hangs.
#[test]
fn torn_frame_is_typed_error_not_hang() {
    let e = engine();
    let procs = 2;
    let steps = 2;
    let p = pool(&e, steps * procs);
    let specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, 1, steps)).collect();
    let mut scripts = vec![FaultScript::clean(); procs];
    scripts[1].torn_sends = vec![1];
    let t0 = Instant::now();
    let runs = run_fake_world(&e, &specs, scripts, CommOpts::fast(), &p);
    assert!(t0.elapsed() < Duration::from_secs(60), "torn frame must fail fast");
    for (r, run) in runs.iter().enumerate() {
        expect_typed(&format!("torn-frame rank {r}"), run);
    }
}

/// A permanent outage on one endpoint: its own sends fail `Permanent`,
/// its peers run into the read timeout — typed errors on every rank.
#[test]
fn permanent_outage_is_typed_on_every_rank() {
    let e = engine();
    let procs = 2;
    let steps = 2;
    let p = pool(&e, steps * procs);
    let specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, 1, steps)).collect();
    let mut scripts = vec![FaultScript::clean(); procs];
    scripts[1].permanent_from = Some(1);
    let t0 = Instant::now();
    let runs = run_fake_world(&e, &specs, scripts, CommOpts::fast(), &p);
    assert!(t0.elapsed() < Duration::from_secs(60), "outage must fail fast");
    for (r, run) in runs.iter().enumerate() {
        expect_typed(&format!("outage rank {r}"), run);
    }
}

/// `kill_at_send`: the endpoint drops dead mid-step (no abort
/// courtesy). The peer detects the death via the liveness flag /
/// closed channel and errors within the timeout.
#[test]
fn kill_at_send_mid_step_is_typed_on_survivors() {
    let e = engine();
    let procs = 2;
    let steps = 2;
    let p = pool(&e, steps * procs);
    let specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, 1, steps)).collect();
    let mut scripts = vec![FaultScript::clean(); procs];
    scripts[1].kill_at_send = Some(2);
    let t0 = Instant::now();
    let runs = run_fake_world(&e, &specs, scripts, CommOpts::fast(), &p);
    assert!(t0.elapsed() < Duration::from_secs(60), "peer death must fail fast");
    for (r, run) in runs.iter().enumerate() {
        expect_typed(&format!("kill-at-send rank {r}"), run);
    }
}

// ----------------------------------------------------- mixed precision

/// Mixed-precision dist: a 2-rank bf16 world in both collective modes
/// lands on the same bits as the single-process bf16 run over the same
/// shard stream. Every rank rounds its gradient partial through the
/// wire dtype before the fixed-shape fold, so all ranks fold identical
/// inputs and the cross-process/in-process boundary stays invisible —
/// the same claim the f32 suite makes, at 16 bits.
#[test]
fn bf16_worlds_match_single_process_bf16_bitwise() {
    use hybridnmt::tensor::half::SlabDtype;
    let e = engine();
    let steps = 2;
    let procs = 2;
    let p = pool(&e, steps * procs);

    let exp = test_exp(&e);
    let mut tr = Trainer::new(&e, &exp).unwrap();
    tr.set_bucket_bytes(BUCKET);
    tr.set_precision(SlabDtype::Bf16).unwrap();
    tr.set_pipeline(procs, 1);
    for s in 0..steps {
        tr.train_step_micro(&p[s * procs..(s + 1) * procs])
            .unwrap_or_else(|err| panic!("bf16 reference step {s}: {err:#}"));
    }
    let reference = tr.params().clone();

    for mode in [DistMode::Ps, DistMode::Replicated] {
        let specs: Vec<RankSpec> = (0..procs)
            .map(|_| {
                let mut s = dist_spec(&e, mode, 1, steps);
                s.precision = SlabDtype::Bf16;
                s
            })
            .collect();
        let runs =
            run_fake_world(&e, &specs, vec![FaultScript::clean(); procs], CommOpts::fast(), &p);
        for (r, run) in runs.into_iter().enumerate() {
            let label = format!("bf16 {mode:?} rank {r}");
            let run = run.unwrap_or_else(|err| panic!("{label}: {err:#}"));
            assert_params_bitwise(&label, &reference, &run.params);
        }
    }
    // The run really was 16-bit: every final parameter survives a
    // round-trip through bf16 unchanged.
    for (name, t) in &reference {
        for &v in t.data() {
            assert_eq!(
                SlabDtype::Bf16.round(v).to_bits(),
                v.to_bits(),
                "`{name}` holds {v}, which is not bf16-representable"
            );
        }
    }
}

/// Ranks disagreeing on `--precision` must fail with a typed
/// dtype-mismatch error at the first gradient exchange — never a
/// silently mixed-precision fold.
#[test]
fn mixed_precision_world_is_rejected() {
    use hybridnmt::tensor::half::SlabDtype;
    let e = engine();
    let procs = 2;
    let steps = 2;
    let p = pool(&e, steps * procs);
    let mut specs: Vec<RankSpec> =
        (0..procs).map(|_| dist_spec(&e, DistMode::Ps, 1, steps)).collect();
    specs[0].precision = SlabDtype::Bf16; // rank 1 stays f32
    let runs = run_fake_world(&e, &specs, vec![FaultScript::clean(); procs], CommOpts::fast(), &p);
    let msgs: Vec<String> = runs
        .iter()
        .enumerate()
        .map(|(r, run)| expect_typed(&format!("mixed-precision rank {r}"), run))
        .collect();
    assert!(
        msgs.iter().any(|m| m.contains("dtype mismatch")),
        "some rank must name the dtype mismatch: {msgs:?}"
    );
}
