//! Property-based tests (hand-rolled generators on `rng::Rng`; the
//! offline build has no proptest). Each property sweeps many random
//! instances of the coordinator invariants: plan well-formedness across
//! random model shapes, simulator conservation laws, tokenizer
//! round-trips, JSON round-trips, and batching/masking structure.

use hybridnmt::config::{HwConfig, ModelDims, Strategy};
use hybridnmt::data::bpe::Bpe;
use hybridnmt::data::synthetic::{Corpus, GenConfig};
use hybridnmt::data::Batcher;
use hybridnmt::dist::wire::{self, Frame, FrameKind, WireError};
use hybridnmt::model_spec::param_specs;
use hybridnmt::parallel::{build_plan, Op};
use hybridnmt::rng::Rng;
use hybridnmt::serve::{Coalescer, Group, Pending};
use hybridnmt::sim::{cost, simulate};
use hybridnmt::tensor::Tensor;
use hybridnmt::util::json::Json;

fn random_dims(rng: &mut Rng) -> ModelDims {
    let gpus = 4;
    let batch = 4 * rng.range(1, 5); // 4..16, divisible by gpus
    ModelDims {
        name: "prop".into(),
        d: 8 * rng.range(1, 4),
        h: 8 * rng.range(1, 5),
        layers: rng.range(1, 5),
        vocab: 32 * rng.range(1, 4),
        batch,
        gpus,
        shard: batch / gpus,
        max_src: rng.range(2, 10),
        max_tgt: rng.range(2, 10),
        beam: 4,
    }
}

/// Every strategy builds a valid SSA/topological plan for random dims,
/// and its gradient outputs exactly cover the parameter inventory.
#[test]
fn prop_plans_valid_and_grads_complete() {
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..40 {
        let dims = random_dims(&mut rng);
        for st in Strategy::ALL {
            let plan = build_plan(&dims, st, rng.chance(0.5));
            plan.validate()
                .unwrap_or_else(|e| panic!("trial {trial} {st:?} dims {dims:?}: {e}"));
            let specs = param_specs(&dims, st.uses_input_feeding());
            assert_eq!(plan.grad_out.len(), specs.len(), "trial {trial} {st:?}");
            for sp in &specs {
                assert!(plan.param_in.contains_key(&sp.name));
                assert!(plan.grad_out.contains_key(&sp.name));
            }
        }
    }
}

/// Simulator conservation laws: makespan bounded below by the busiest
/// device and by the single-device critical work / G, and bounded above
/// by fully-serial execution; busy time never exceeds G * makespan.
#[test]
fn prop_sim_conservation() {
    let mut rng = Rng::new(0xBEEF);
    let hw = HwConfig::default();
    for _ in 0..25 {
        let dims = random_dims(&mut rng);
        for st in Strategy::ALL {
            let plan = build_plan(&dims, st, true);
            let r = simulate(&plan, &hw);
            let busiest = r.device_busy.iter().cloned().fold(0.0, f64::max);
            assert!(
                r.makespan + 1e-12 >= busiest,
                "{st:?}: makespan {} < busiest {}",
                r.makespan,
                busiest
            );
            let serial: f64 = plan
                .steps
                .iter()
                .map(|s| match &s.op {
                    Op::Exec { .. } | Op::Add if s.device != hybridnmt::parallel::plan::HOST => {
                        cost::compute_time(&s.cost, &hw)
                    }
                    _ => 0.0,
                })
                .sum();
            assert!(r.makespan <= serial + r.sync_time + r.transfer_time + 1e-9);
            let total_busy: f64 = r.device_busy.iter().sum();
            assert!(total_busy <= hw.gpus as f64 * r.makespan + 1e-9);
        }
    }
}

/// The simulator is a pure function of (plan, hw).
#[test]
fn prop_sim_deterministic() {
    let mut rng = Rng::new(7);
    let hw = HwConfig::default();
    for _ in 0..10 {
        let dims = random_dims(&mut rng);
        let plan = build_plan(&dims, Strategy::Hybrid, true);
        let a = simulate(&plan, &hw);
        let b = simulate(&plan, &hw);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.device_busy, b.device_busy);
    }
}

/// Hybrid's synchronized bytes are exactly the attention parameters —
/// independent of model size (the paper's central cost argument).
#[test]
fn prop_hybrid_sync_bytes_equal_attention_params() {
    let mut rng = Rng::new(11);
    for _ in 0..20 {
        let dims = random_dims(&mut rng);
        let plan = build_plan(&dims, Strategy::Hybrid, true);
        let ar_bytes: f64 = plan
            .steps
            .iter()
            .map(|s| match &s.op {
                Op::AllReduce { bytes, .. } => *bytes,
                _ => 0.0,
            })
            .sum();
        let attn_bytes = 4.0
            * (dims.h * dims.h + 2 * dims.h * dims.h + dims.h * dims.vocab + dims.vocab) as f64;
        assert!((ar_bytes - attn_bytes).abs() < 1.0, "{ar_bytes} vs {attn_bytes}");
    }
}

/// BPE: encoding any word from the training distribution and rejoining
/// the pieces reproduces the word; all emitted symbols are in symbols().
#[test]
fn prop_bpe_roundtrip() {
    let mut rng = Rng::new(0xB9E);
    for trial in 0..15 {
        let corpus = Corpus::generate(
            "p",
            300,
            0,
            0,
            &GenConfig::for_dims(24, 0.0, rng.next_u64()),
        );
        let wf = corpus.word_freq();
        let bpe = Bpe::train(&wf, rng.range(10, 200));
        let symbols: std::collections::HashSet<String> =
            bpe.symbols(&wf).into_iter().collect();
        for w in wf.keys().take(50) {
            let pieces = bpe.encode_word(w);
            let rejoined: String = pieces
                .iter()
                .map(|p| p.strip_suffix("@@").unwrap_or(p))
                .collect();
            assert_eq!(&rejoined, w, "trial {trial}");
            for p in &pieces {
                assert!(symbols.contains(p), "trial {trial}: `{p}` not in symbol set");
            }
        }
    }
}

/// Batches always respect the mask discipline: tmask is a prefix,
/// tgt_out under the mask is non-PAD and ends with EOS, src is PAD
/// exactly after srclen.
#[test]
fn prop_batch_mask_discipline() {
    let mut rng = Rng::new(0xDA7A);
    for _ in 0..8 {
        let m = rng.range(12, 25);
        let corpus =
            Corpus::generate("p", 600, 30, 30, &GenConfig::for_dims(m, 0.3, rng.next_u64()));
        let bsz = 4 * rng.range(1, 3);
        let mut batcher = Batcher::new(&corpus, 256, bsz, m, m, rng.next_u64()).unwrap();
        for _ in 0..5 {
            let batch = batcher.next_train();
            for bi in 0..bsz {
                let len = batch.srclen.data()[bi] as usize;
                assert!(len >= 1 && len <= m);
                assert!(batch.src.data()[bi * m + len..(bi + 1) * m].iter().all(|&x| x == 0));
                let mask = &batch.tmask.data()[bi * m..(bi + 1) * m];
                let tlen = mask.iter().filter(|&&x| x > 0.0).count();
                assert!(tlen >= 1);
                // Prefix property.
                assert!(mask[..tlen].iter().all(|&x| x == 1.0));
                assert!(mask[tlen..].iter().all(|&x| x == 0.0));
                assert_eq!(batch.tgt_out.data()[bi * m + tlen - 1], 2 /* EOS */);
            }
        }
    }
}

/// Tensor shard/gather round trips for random shapes.
#[test]
fn prop_tensor_shard_roundtrip() {
    let mut rng = Rng::new(5);
    for _ in 0..50 {
        let rows = 4 * rng.range(1, 6);
        let cols = rng.range(1, 12);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(1.0)).collect();
        let t = Tensor::new(vec![rows, cols], data);
        let g = 4;
        let per = rows / g;
        let shards: Vec<Tensor> = (0..g).map(|i| t.slice0(i * per, (i + 1) * per)).collect();
        let refs: Vec<&Tensor> = shards.iter().collect();
        assert_eq!(Tensor::concat0(&refs), t);
        // gather_rows with identity is the identity.
        let idx: Vec<usize> = (0..rows).collect();
        assert_eq!(t.gather_rows(&idx), t);
    }
}

/// JSON parser round-trips random documents generated from the writer.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| char::from_u32(rng.range(32, 1200) as u32).unwrap_or('x'))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(0x1503);
    for _ in 0..200 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, doc, "{text}");
    }
}

/// Input-feeding plans contain strictly more serial structure: for the
/// same dims, the simulated hybrid makespan never exceeds hybrid_if.
#[test]
fn prop_removing_input_feeding_never_slower() {
    let mut rng = Rng::new(42);
    let hw = HwConfig::default();
    for _ in 0..15 {
        let dims = random_dims(&mut rng);
        let hybrid = simulate(&build_plan(&dims, Strategy::Hybrid, true), &hw).makespan;
        let hybrid_if = simulate(&build_plan(&dims, Strategy::HybridIf, true), &hw).makespan;
        assert!(
            hybrid <= hybrid_if * 1.02,
            "dims {dims:?}: hybrid {hybrid} vs IF {hybrid_if}"
        );
    }
}

/// The length-bucketed coalescer is a lossless partition: for any
/// arrival permutation of the same request set, every request ends up
/// in exactly one group (no drop, no duplicate), groups never exceed
/// capacity, and each group is length-homogeneous (one bucket). The
/// served *tokens* are then permutation-independent by construction —
/// each sentence's beam search is self-contained — which
/// `rust/tests/serve_equivalence.rs` asserts end-to-end on the engine.
#[test]
fn prop_coalescer_partitions_any_arrival_order() {
    let mut rng = Rng::new(0xC0A1);
    for trial in 0..20 {
        let n = rng.range(1, 60);
        let capacity = rng.range(1, 9);
        let bucket_width = rng.range(1, 6);
        // One shared request set...
        let reqs: Vec<Pending> = (0..n)
            .map(|i| Pending {
                id: i as u64,
                src: vec![5; rng.range(1, 24)],
                t_submit: 0.0,
            })
            .collect();
        // ...pushed in a random order.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut co = Coalescer::new(capacity, bucket_width, 1.0);
        let mut groups: Vec<Group> = Vec::new();
        for &i in &order {
            groups.extend(co.push(reqs[i].clone()));
        }
        groups.extend(co.drain());
        assert_eq!(co.pending(), 0, "trial {trial}");
        let mut seen: Vec<u64> = Vec::new();
        for g in &groups {
            assert!(g.reqs.len() <= capacity, "trial {trial}: oversized group");
            assert!(!g.reqs.is_empty(), "trial {trial}: empty group");
            // Length homogeneity: all members share a bucket.
            let key0 = (g.reqs[0].src.len() - 1) / bucket_width;
            for r in &g.reqs {
                assert_eq!((r.src.len() - 1) / bucket_width, key0, "trial {trial}");
            }
            seen.extend(g.reqs.iter().map(|r| r.id));
        }
        seen.sort_unstable();
        let want: Vec<u64> = (0..n as u64).collect();
        assert_eq!(seen, want, "trial {trial}: groups must partition the request set");
    }
}

/// Uniform-length traffic packs tightly: with every request in one
/// bucket and no deadline flushes, only the final group can be partial,
/// so the mean batch-fill ratio is bounded below by n / (cap * ceil(n /
/// cap)) — and in particular full groups dominate once n >> cap.
#[test]
fn prop_coalescer_fill_floor_for_uniform_traffic() {
    let mut rng = Rng::new(0xF111);
    for trial in 0..20 {
        let capacity = rng.range(2, 9);
        let n = rng.range(capacity, 12 * capacity);
        let len = rng.range(1, 20);
        let mut co = Coalescer::new(capacity, 4, 1.0);
        let mut groups: Vec<Group> = Vec::new();
        for i in 0..n {
            groups.extend(co.push(Pending {
                id: i as u64,
                src: vec![7; len],
                t_submit: 0.0,
            }));
        }
        groups.extend(co.drain());
        let n_groups = n.div_ceil(capacity);
        assert_eq!(groups.len(), n_groups, "trial {trial}");
        let mean_fill: f64 =
            groups.iter().map(Group::fill_ratio).sum::<f64>() / groups.len() as f64;
        let floor = n as f64 / (capacity * n_groups) as f64;
        assert!(
            mean_fill + 1e-12 >= floor,
            "trial {trial}: mean fill {mean_fill} below floor {floor}"
        );
        // All groups but possibly the last are full.
        for g in &groups[..groups.len() - 1] {
            assert_eq!(g.fill_ratio(), 1.0, "trial {trial}");
        }
    }
}

// --------------------------------------------------------------------------
// Checkpoint truncation sweep (robustness: torn files load as errors)
// --------------------------------------------------------------------------

use hybridnmt::optim::{MomentRowsView, OptimStateView};
use hybridnmt::train::checkpoint::{self, TrainMeta};
use std::collections::BTreeMap;

/// A small random parameter map plus matching Adam moment rows — tiny
/// on purpose so the per-byte truncation sweep below stays cheap.
fn random_checkpoint_state(
    rng: &mut Rng,
) -> (BTreeMap<String, Tensor>, BTreeMap<String, Vec<f32>>, BTreeMap<String, Vec<f32>>) {
    let mut params = BTreeMap::new();
    let mut m = BTreeMap::new();
    let mut v = BTreeMap::new();
    let n_params = rng.range(1, 4);
    for i in 0..n_params {
        let name = format!("p{i}_w");
        let n = rng.range(1, 8);
        let data: Vec<f32> = (0..n).map(|_| rng.uniform(1.0)).collect();
        params.insert(name.clone(), Tensor::new(vec![n], data));
        m.insert(name.clone(), (0..n).map(|_| rng.uniform(0.1)).collect());
        v.insert(name, (0..n).map(|_| rng.uniform(0.1)).collect());
    }
    (params, m, v)
}

/// Every proper prefix of a valid v2 checkpoint — a torn write frozen
/// at any byte — must load as a clean `Err`, never a panic and never a
/// silently-shortened checkpoint. The format is self-delimiting with a
/// trailing EOF check, so no strict prefix can parse.
#[test]
fn prop_every_truncated_checkpoint_prefix_errors() {
    let mut rng = Rng::new(0xC4C4);
    for trial in 0..8 {
        let (params, m, v) = random_checkpoint_state(&mut rng);
        let view = OptimStateView {
            kind: "adam",
            lr: 1e-3,
            t: 5 + trial,
            rows: MomentRowsView::Maps { m: &m, v: &v },
        };
        let meta = TrainMeta {
            steps_done: 7 + trial,
            micro_consumed: 28,
            sim_clock: 12.5,
            prev_dev_ppl: if trial % 2 == 0 { Some(33.25) } else { None },
            ..TrainMeta::default()
        };
        let bytes = checkpoint::to_bytes(&params, &view, &meta).unwrap();

        // The untruncated buffer round-trips exactly.
        let full = checkpoint::load_full_bytes(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: full buffer must load: {e:#}"));
        assert_eq!(full.params.len(), params.len(), "trial {trial}");
        assert_eq!(full.meta, meta, "trial {trial}");
        let opt = full.opt.expect("v2 carries optimizer state");
        assert_eq!(opt.kind, "adam", "trial {trial}");
        assert_eq!(opt.t, 5 + trial, "trial {trial}");

        // ...and every stepped prefix is a clean error.
        for cut in 0..bytes.len() {
            assert!(
                checkpoint::load_full_bytes(&bytes[..cut]).is_err(),
                "trial {trial}: prefix of {cut}/{} bytes must not parse",
                bytes.len()
            );
        }
    }
}

/// Single-byte corruption anywhere in a checkpoint never panics: it
/// either fails the parse (counts/lengths are bounds-checked against
/// the buffer) or decodes to different-but-well-formed values. Flipped
/// length fields are the interesting case — a naive reader would
/// attempt a multi-gigabyte allocation.
#[test]
fn prop_corrupt_checkpoint_bytes_never_panic() {
    let mut rng = Rng::new(0xBADC);
    let (params, m, v) = random_checkpoint_state(&mut rng);
    let view =
        OptimStateView { kind: "adam", lr: 1e-3, t: 3, rows: MomentRowsView::Maps { m: &m, v: &v } };
    let bytes = checkpoint::to_bytes(&params, &view, &TrainMeta::default()).unwrap();
    for _trial in 0..200 {
        let mut evil = bytes.clone();
        let pos = rng.range(0, evil.len());
        let flip = 1u8 << rng.range(0, 8);
        evil[pos] ^= flip;
        // Must return (Ok or Err), not panic or OOM-abort.
        let _ = checkpoint::load_full_bytes(&evil);
    }
    // All-0xFF counts: the worst-case "allocate u32::MAX rows" input.
    let mut evil = bytes.clone();
    for b in &mut evil[8..12] {
        *b = 0xFF;
    }
    assert!(checkpoint::load_full_bytes(&evil).is_err(), "absurd param count must be rejected");
}

/// The params-only `load` path on a truncated v2 file: any cut inside
/// the parameter section errors; a cut at-or-past the end of the
/// parameter section loads the params (v2 files legitimately carry
/// optimizer state after them, so no EOF check applies).
#[test]
fn prop_truncated_checkpoint_file_load_boundary_is_exact() {
    let mut rng = Rng::new(0x70C7);
    let (params, m, v) = random_checkpoint_state(&mut rng);
    let view =
        OptimStateView { kind: "adam", lr: 1e-3, t: 9, rows: MomentRowsView::Maps { m: &m, v: &v } };
    let bytes = checkpoint::to_bytes(&params, &view, &TrainMeta::default()).unwrap();

    let dir = std::env::temp_dir().join("hynmt_prop_trunc");
    std::fs::create_dir_all(&dir).unwrap();
    // The v1 file of the same params has the same length as the v2
    // magic + parameter section, which locates the section boundary.
    let v1_path = dir.join("v1.bin");
    checkpoint::save(&v1_path, &params).unwrap();
    let boundary = std::fs::metadata(&v1_path).unwrap().len() as usize;
    assert!(boundary <= bytes.len());

    let path = dir.join("cut.bin");
    let mut cuts: Vec<usize> = (0..bytes.len()).step_by(13).collect();
    cuts.extend([boundary - 1, boundary, bytes.len()]);
    for cut in cuts {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let got = checkpoint::load(&path);
        if cut < boundary {
            assert!(got.is_err(), "cut {cut} < boundary {boundary} must fail");
        } else {
            let loaded = got.unwrap_or_else(|e| panic!("cut {cut} >= boundary {boundary}: {e:#}"));
            assert_eq!(loaded.len(), params.len(), "cut {cut}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------- dist wire protocol

fn random_wire_frame(rng: &mut Rng) -> Frame {
    let kinds = [
        FrameKind::Hello,
        FrameKind::Roster,
        FrameKind::RingHello,
        FrameKind::Grad,
        FrameKind::Param,
        FrameKind::Meta,
        FrameKind::Done,
        FrameKind::Abort,
    ];
    let kind = kinds[rng.range(0, kinds.len())];
    let payload: Vec<u8> = (0..rng.range(0, 600)).map(|_| rng.range(0, 256) as u8).collect();
    Frame::new(
        kind,
        rng.range(0, 64) as u32,
        rng.range(0, 1 << 20) as u64,
        rng.range(0, 512) as u32,
        payload,
    )
}

/// Encode/decode round-trip over random frames, including random
/// bucket segments through the f32 payload codec.
#[test]
fn prop_wire_roundtrip_random_frames() {
    let mut rng = Rng::new(0xD157_0001);
    for _ in 0..300 {
        let f = random_wire_frame(&mut rng);
        let bytes = wire::encode(&f);
        assert_eq!(bytes.len(), wire::frame_size(f.payload.len()));
        let back = wire::decode_exact(&bytes)
            .unwrap_or_else(|e| panic!("roundtrip of {:?} failed: {e}", f.kind));
        assert_eq!(back, f);
    }
    // Bucket segments: random f32 slices survive the payload codec
    // bit-for-bit inside a Grad frame.
    for i in 0..50 {
        let seg: Vec<f32> = (0..rng.range(1, 2000))
            .map(|_| rng.uniform(1.0) * 10f32.powi(rng.range(0, 8) as i32 - 4))
            .collect();
        let f = Frame::new(FrameKind::Grad, 1, i, 0, wire::f32s_to_bytes(&seg));
        let back = wire::decode_exact(&wire::encode(&f)).unwrap();
        let seg2 = wire::bytes_to_f32s(&back.payload).unwrap();
        assert_eq!(seg.len(), seg2.len());
        for (a, b) in seg.iter().zip(seg2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

/// Decode a buffer as a stream of frames; Err carries the failure of
/// the frame the cut landed in.
fn decode_stream(mut buf: &[u8]) -> Result<Vec<Frame>, WireError> {
    let mut out = Vec::new();
    while !buf.is_empty() {
        let (f, used) = wire::decode(buf)?;
        out.push(f);
        buf = &buf[used..];
    }
    Ok(out)
}

/// Every proper prefix of a valid multi-frame stream decodes to a
/// clean typed error (a torn final frame), and every frame-boundary
/// prefix decodes to exactly the frames before the cut. Nothing
/// panics, nothing is silently mis-framed.
#[test]
fn prop_every_wire_stream_prefix_is_typed() {
    let mut rng = Rng::new(0xD157_0002);
    for _ in 0..20 {
        let frames: Vec<Frame> = (0..3).map(|_| random_wire_frame(&mut rng)).collect();
        let mut stream = Vec::new();
        let mut boundaries = vec![0usize];
        for f in &frames {
            stream.extend_from_slice(&wire::encode(f));
            boundaries.push(stream.len());
        }
        for cut in 0..stream.len() {
            match decode_stream(&stream[..cut]) {
                Ok(decoded) => {
                    let k = boundaries.iter().position(|&b| b == cut).unwrap_or_else(|| {
                        panic!("cut {cut} decoded Ok but is not a frame boundary")
                    });
                    assert_eq!(decoded, frames[..k], "boundary cut {cut}");
                }
                Err(WireError::Truncated { need, have }) => {
                    assert!(have < need, "cut {cut}: nonsense truncation {have}/{need}");
                    assert!(
                        !boundaries.contains(&cut),
                        "cut {cut} is a boundary but decoded Truncated"
                    );
                }
                Err(e) => panic!("cut {cut}: expected Truncated, got {e}"),
            }
        }
        let full = decode_stream(&stream).unwrap();
        assert_eq!(full, frames);
    }
}

/// Flipping any single bit anywhere in an encoded frame — magic,
/// length, header, payload, checksum — makes decode return a typed
/// error, never a wrong frame and never a panic.
#[test]
fn prop_wire_single_bit_corruption_always_detected() {
    let mut rng = Rng::new(0xD157_0003);
    for _ in 0..8 {
        let f = random_wire_frame(&mut rng);
        let clean = wire::encode(&f);
        for i in 0..clean.len() {
            let mut bad = clean.clone();
            bad[i] ^= 1 << rng.range(0, 8);
            let got = wire::decode_exact(&bad);
            assert!(
                got.is_err(),
                "flipping byte {i}/{} of a {:?} frame decoded Ok",
                clean.len(),
                f.kind
            );
        }
    }
}

/// Random byte soup (no magic) is rejected, not mis-framed: decode
/// errors on arbitrary garbage of any length.
#[test]
fn prop_wire_garbage_never_panics() {
    let mut rng = Rng::new(0xD157_0004);
    for _ in 0..200 {
        let soup: Vec<u8> = (0..rng.range(0, 64)).map(|_| rng.range(0, 256) as u8).collect();
        assert!(wire::decode(&soup).is_err(), "garbage decoded Ok: {soup:?}");
    }
}

// --------------------------------------- deficit round-robin fairness

use hybridnmt::metrics::hll::DEFAULT_PRECISION;
use hybridnmt::metrics::Hll;
use hybridnmt::serve::{Drr, ZipfSampler};

/// Work conservation: for any random mix of queues, items, costs and
/// weights, `pop` yields an item whenever any queue is non-empty, every
/// enqueued item comes back exactly once, and each item is returned
/// under the queue name it was enqueued to.
#[test]
fn prop_drr_is_work_conserving_and_lossless() {
    let mut rng = Rng::new(0xD88_0001);
    for trial in 0..30 {
        let quantum = rng.range(1, 9) as u64;
        let mut drr: Drr<u64> = Drr::new(quantum);
        let n_queues = rng.range(1, 6);
        let mut expected: Vec<Vec<u64>> = vec![Vec::new(); n_queues];
        let mut total = 0usize;
        for q in 0..n_queues {
            let name = format!("q{q}");
            drr.set_weight(&name, rng.range(1, 4) as u64);
            for _ in 0..rng.range(0, 20) {
                let item = rng.next_u64();
                let cost = rng.range(0, 12) as u64; // 0 exercises the ≥1 clamp
                drr.enqueue(&name, item, cost);
                expected[q].push(item);
                total += 1;
            }
        }
        assert_eq!(drr.len(), total, "trial {trial}");
        let mut got: Vec<Vec<u64>> = vec![Vec::new(); n_queues];
        for served in 0..total {
            let (name, item) = drr
                .pop()
                .unwrap_or_else(|| panic!("trial {trial}: pop None with {} left", total - served));
            let q: usize = name[1..].parse().unwrap();
            got[q].push(item);
        }
        assert!(drr.pop().is_none(), "trial {trial}: drained scheduler must return None");
        assert!(drr.is_empty(), "trial {trial}");
        // Per-queue FIFO, nothing lost, nothing duplicated.
        assert_eq!(got, expected, "trial {trial}");
    }
}

/// Bounded deficit ⇒ no starvation: at every point of any schedule, a
/// queue's unspent deficit is below `quantum × weight + max_cost` —
/// credit cannot be banked without bound, so a backlogged queue is
/// served at least once every `⌈max_cost / (quantum × weight)⌉` rounds
/// no matter how hard the other queues flood.
#[test]
fn prop_drr_deficit_is_bounded() {
    let mut rng = Rng::new(0xD88_0002);
    for trial in 0..25 {
        let quantum = rng.range(1, 6) as u64;
        let max_cost = rng.range(1, 10) as u64;
        let mut drr: Drr<usize> = Drr::new(quantum);
        let names: Vec<String> = (0..rng.range(2, 5)).map(|q| format!("q{q}")).collect();
        let mut weights = std::collections::BTreeMap::new();
        for name in &names {
            let w = rng.range(1, 4) as u64;
            drr.set_weight(name, w);
            weights.insert(name.clone(), w);
            for i in 0..rng.range(1, 40) {
                drr.enqueue(name, i, rng.range(1, max_cost as usize + 1) as u64);
            }
        }
        while drr.pop().is_some() {
            for name in &names {
                let bound = quantum * weights[name] + max_cost;
                assert!(
                    drr.deficit(name) < bound,
                    "trial {trial}: queue {name} banked deficit {} ≥ bound {bound}",
                    drr.deficit(name)
                );
            }
        }
    }
}

/// A flooding hot tenant cannot starve a cold one: with equal weights
/// and unit costs, the cold queue's entire (≤ quantum) backlog is
/// served within the first two rounds — i.e. within `2 × quantum` pops
/// — even when the hot queue holds 20× the work.
#[test]
fn prop_drr_flooding_queue_cannot_starve_the_cold_one() {
    let mut rng = Rng::new(0xD88_0003);
    for trial in 0..20 {
        let quantum = rng.range(2, 9) as u64;
        let cold_n = rng.range(1, quantum as usize + 1);
        let mut drr: Drr<u32> = Drr::new(quantum);
        for i in 0..(20 * quantum) as u32 {
            drr.enqueue("hot", i, 1);
        }
        for i in 0..cold_n as u32 {
            drr.enqueue("cold", i, 1);
        }
        let mut cold_done_at = None;
        let mut pops = 0usize;
        while let Some((name, _)) = drr.pop() {
            pops += 1;
            if name == "cold" && drr.queue_len("cold") == 0 {
                cold_done_at = Some(pops);
                break;
            }
        }
        let done = cold_done_at
            .unwrap_or_else(|| panic!("trial {trial}: cold queue never fully served"));
        assert!(
            done <= 2 * quantum as usize,
            "trial {trial}: cold backlog of {cold_n} took {done} pops (quantum {quantum})"
        );
    }
}

/// Weights shape the share: with unit costs and both queues saturated,
/// a weight-2 queue is served exactly twice as often as a weight-1
/// queue over any whole number of rounds.
#[test]
fn prop_drr_weighted_share_is_proportional() {
    let mut rng = Rng::new(0xD88_0004);
    for trial in 0..20 {
        let quantum = rng.range(1, 7) as u64;
        let mut drr: Drr<u32> = Drr::new(quantum);
        // Both queues hold far more than the pops we take, so neither
        // empties (an emptied queue forfeits credit and skews counts).
        for i in 0..1000u32 {
            drr.enqueue("heavy", i, 1);
            drr.enqueue("light", i, 1);
        }
        drr.set_weight("heavy", 2);
        drr.set_weight("light", 1);
        let rounds = rng.range(2, 8) as u64;
        let per_round = (3 * quantum) as usize; // 2q heavy + q light
        let mut heavy = 0u64;
        let mut light = 0u64;
        for _ in 0..rounds as usize * per_round {
            match drr.pop().unwrap().0.as_str() {
                "heavy" => heavy += 1,
                _ => light += 1,
            }
        }
        assert_eq!(heavy, 2 * quantum * rounds, "trial {trial}");
        assert_eq!(light, quantum * rounds, "trial {trial}");
    }
}

// ------------------------------------------------ HyperLogLog accuracy

/// HLL error bounds at the cardinalities the serving bench reports:
/// near-exact at 10 (linear-counting regime), within 5 % at 1e3 and
/// 1e5 (the raw-estimator standard error at p = 12 is ~1.6 %, so 3σ is
/// ~5 %). Items are drawn as disjoint random streams, so this also
/// checks the internal mixer handles arbitrary (not just sequential)
/// identities.
#[test]
fn prop_hll_error_is_bounded_at_bench_cardinalities() {
    for (truth, tol_frac, seed) in
        [(10u64, 0.0, 1u64), (1_000, 0.05, 2), (100_000, 0.05, 3)]
    {
        let h = Hll::new(DEFAULT_PRECISION);
        let mut rng = Rng::new(0x4115_0000 ^ seed);
        // Distinct by construction: disjoint high bits per index.
        let salt = rng.next_u64() >> 20;
        for i in 0..truth {
            h.insert_u64((salt << 20) | i);
            if i % 3 == 0 {
                h.insert_u64((salt << 20) | i); // duplicates must not inflate
            }
        }
        let est = h.estimate();
        let err = (est - truth as f64).abs();
        let tol = if truth <= 10 { 1.0 } else { truth as f64 * tol_frac };
        assert!(
            err <= tol,
            "cardinality {truth}: estimate {est} off by {err} (tolerance {tol})"
        );
    }
}

// ---------------------------------------------------- Zipf CDF shape

/// For any (n, s), the sampler's CDF equals the directly-computed
/// normalized partial sums of `1/(k+1)^s` (to 1e-12), is monotone
/// nondecreasing, and terminates at exactly 1 — so every uniform draw
/// maps to a valid rank and the closed-form spot checks in
/// `serve::loadgen` generalize.
#[test]
fn prop_zipf_cdf_is_exact_for_random_shapes() {
    let mut rng = Rng::new(0x21FF);
    for trial in 0..40 {
        let n = rng.range(1, 40);
        let s = rng.f64() * 3.0;
        let z = ZipfSampler::new(n, s);
        assert_eq!(z.len(), n);
        let h: f64 = (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).sum();
        let mut acc = 0.0;
        let mut prev = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            let expect = if k + 1 == n { 1.0 } else { acc / h };
            assert!(
                (z.cdf(k) - expect).abs() < 1e-12,
                "trial {trial}: cdf({k}) = {}, partial sum {expect}",
                z.cdf(k)
            );
            assert!(z.cdf(k) + 1e-15 >= prev, "trial {trial}: CDF must be monotone");
            prev = z.cdf(k);
        }
        assert_eq!(z.cdf(n - 1), 1.0, "trial {trial}: CDF must end at exactly 1");
        for _ in 0..50 {
            assert!(z.sample(&mut rng) < n, "trial {trial}: sample out of range");
        }
    }
}
