//! Multi-replica training equivalence: for a fixed global batch (the
//! same ordered list of micro-batch row-shards), the final parameters
//! after N optimizer steps must be **bitwise-identical** no matter how
//! the shards are spread over replicas, how many accumulation
//! micro-steps each replica runs, which plan executor
//! (sequential/parallel) walks the graph — or which step engine runs
//! the update: the flat-slab overlapped bucketed reduce (the default)
//! vs the map-based PR-4 reference, at every bucket size. The
//! fixed-shape gradient tree, the index-only bucket boundaries and the
//! partition-insensitive optimizer make this hold by construction;
//! this suite is the gate (requires `make artifacts`).
//!
//! Also here: optimizer-trait parity against the seed `Optimizer`
//! numerics on the quadratic fixtures (engine-free), and exact
//! checkpoint-v2 resume through the slab round-trip.

use hybridnmt::config::{
    DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig,
};
use hybridnmt::data::vocab::{BOS, EOS, PAD};
use hybridnmt::optim::{self, Optimizer};
use hybridnmt::parallel::Batch;
use hybridnmt::rng::Rng;
use hybridnmt::runtime::Engine;
use hybridnmt::tensor::half::SlabDtype;
use hybridnmt::tensor::{ITensor, Tensor};
use hybridnmt::train::{StepMode, Trainer};
use std::collections::BTreeMap;

/// 256 KiB — the default bucket size, named for the bucket-size sweep.
const KIB256: usize = 256 * 1024;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

/// A deterministic random batch padded to the artifact shapes.
fn random_batch(d: &ModelDims, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, m, n) = (d.batch, d.max_src, d.max_tgt);
    let mut src = vec![PAD; b * m];
    let mut srclen = vec![0i32; b];
    let mut tgt_in = vec![PAD; b * n];
    let mut tgt_out = vec![PAD; b * n];
    let mut tmask = vec![0.0f32; b * n];
    for bi in 0..b {
        let sl = rng.range(2, m + 1);
        srclen[bi] = sl as i32;
        for t in 0..sl {
            src[bi * m + t] = rng.range(4, d.vocab) as i32;
        }
        let tl = rng.range(1, n);
        tgt_in[bi * n] = BOS;
        for t in 0..tl {
            let tok = rng.range(4, d.vocab) as i32;
            tgt_in[bi * n + t + 1] = tok;
            tgt_out[bi * n + t] = tok;
        }
        tgt_out[bi * n + tl] = EOS;
        for t in 0..=tl {
            tmask[bi * n + t] = 1.0;
        }
    }
    Batch {
        src: ITensor::new(vec![b, m], src),
        srclen: ITensor::new(vec![b], srclen),
        tgt_in: ITensor::new(vec![b, n], tgt_in),
        tgt_out: ITensor::new(vec![b, n], tgt_out),
        tmask: Tensor::new(vec![b, n], tmask),
    }
}

fn test_exp(e: &Engine) -> Experiment {
    Experiment {
        model: e.dims().clone(),
        strategy: Strategy::Hybrid,
        hw: HwConfig::default(),
        train: TrainConfig {
            seed: 3,
            steps: 4,
            eval_interval: 100,
            // Every eval hits the plateau-decay check, so the resume
            // test exercises the persisted `prev_dev_ppl` reference.
            decay_interval: 2,
            ..Default::default()
        },
        data: DataConfig::wmt14_sim(600),
        artifacts_dir: "artifacts".into(),
    }
}

/// Train `steps` optimizer steps over `pool` (consumed in order,
/// `replicas × accum` shards per step) with the given step engine and
/// bucket size, and return the final params.
#[allow(clippy::too_many_arguments)]
fn train_mode_config(
    e: &Engine,
    pool: &[Batch],
    steps: usize,
    replicas: usize,
    accum: usize,
    sequential: bool,
    mode: StepMode,
    bucket_bytes: usize,
) -> BTreeMap<String, Tensor> {
    let exp = test_exp(e);
    let mut tr = Trainer::new(e, &exp).unwrap();
    tr.sequential = sequential;
    tr.set_step_mode(mode);
    tr.set_bucket_bytes(bucket_bytes);
    tr.set_pipeline(replicas, accum);
    let per = tr.pipeline.micro_per_step();
    assert_eq!(per, replicas * accum);
    assert!(pool.len() >= steps * per, "pool too small");
    for s in 0..steps {
        tr.train_step_micro(&pool[s * per..(s + 1) * per]).unwrap_or_else(|err| {
            panic!("{replicas}x{accum} {mode:?}/bb={bucket_bytes} step {s}: {err:#}")
        });
    }
    assert_eq!(tr.steps_done(), steps);
    tr.params().clone()
}

/// Default-engine shorthand (flat slabs at the default bucket size).
fn train_config(
    e: &Engine,
    pool: &[Batch],
    steps: usize,
    replicas: usize,
    accum: usize,
    sequential: bool,
) -> BTreeMap<String, Tensor> {
    train_mode_config(e, pool, steps, replicas, accum, sequential, StepMode::Flat, KIB256)
}

fn assert_params_bitwise(label: &str, a: &BTreeMap<String, Tensor>, b: &BTreeMap<String, Tensor>) {
    assert_eq!(a.len(), b.len(), "{label}: param count");
    for (name, x) in a {
        let y = b.get(name).unwrap_or_else(|| panic!("{label}: missing `{name}`"));
        assert_eq!(x.shape(), y.shape(), "{label}: `{name}` shape");
        for (i, (u, v)) in x.data().iter().zip(y.data()).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "{label}: param `{name}`[{i}] {u} vs {v}"
            );
        }
    }
}

/// The tentpole claim: 4 shards per step spread as 1×4, 2×2 and 4×1
/// over sequential and parallel executors — one set of final bits.
#[test]
fn replica_fanout_and_accumulation_are_bitwise_equivalent() {
    let e = engine();
    let d = e.dims().clone();
    let steps = 2;
    let pool: Vec<Batch> = (0..steps * 4).map(|j| random_batch(&d, 100 + j as u64)).collect();

    // Reference: single replica, accumulation only, sequential executor.
    let reference = train_config(&e, &pool, steps, 1, 4, true);
    for (replicas, accum, sequential) in
        [(1, 4, false), (2, 2, false), (4, 1, false), (4, 1, true)]
    {
        let got = train_config(&e, &pool, steps, replicas, accum, sequential);
        assert_params_bitwise(
            &format!("{replicas} replicas x {accum} accum (sequential={sequential})"),
            &reference,
            &got,
        );
    }
}

/// Same invariant at 8 shards per step (covers replicas {2, 4} with
/// accum 4 and 2 against the single-replica accumulated reference).
#[test]
fn eight_shard_global_batch_is_replica_count_invariant() {
    let e = engine();
    let d = e.dims().clone();
    let steps = 2;
    let pool: Vec<Batch> = (0..steps * 8).map(|j| random_batch(&d, 200 + j as u64)).collect();
    let reference = train_config(&e, &pool, steps, 1, 8, true);
    for (replicas, accum) in [(2, 4), (4, 2)] {
        let got = train_config(&e, &pool, steps, replicas, accum, false);
        assert_params_bitwise(&format!("{replicas}x{accum}"), &reference, &got);
    }
}

/// The degenerate 1×1 pipeline must preserve the seed trainer's
/// numerics across both executors (the pre-refactor behavior).
#[test]
fn single_replica_single_accum_matches_across_executors() {
    let e = engine();
    let d = e.dims().clone();
    let pool: Vec<Batch> = (0..3).map(|j| random_batch(&d, 300 + j as u64)).collect();
    let seq = train_config(&e, &pool, 3, 1, 1, true);
    let par = train_config(&e, &pool, 3, 1, 1, false);
    assert_params_bitwise("1x1 seq vs par", &seq, &par);
}

/// The tentpole acceptance gate: the flat-slab overlapped bucketed
/// step reproduces the PR-4 map-based step **bitwise** at every
/// replicas {1,2,4} × accum {1,4} spread and every bucket size —
/// one-param buckets (bucket_bytes=1 closes a bucket after each
/// parameter), the 256 KiB default, and one giant bucket. Bucket
/// boundaries depend only on the index, the per-bucket shard tree is
/// the same tree, and the slab optimizer is the same per-element math,
/// so the bits cannot differ.
#[test]
fn flat_bucketed_step_matches_map_step_bitwise() {
    let e = engine();
    let d = e.dims().clone();
    let steps = 2;
    // Big enough for the largest config (4 replicas × 4 accum).
    let pool: Vec<Batch> =
        (0..steps * 16).map(|j| random_batch(&d, 600 + j as u64)).collect();
    for (replicas, accum) in [(1, 1), (2, 1), (4, 1), (1, 4), (2, 4), (4, 4)] {
        let n = steps * replicas * accum;
        let map_ref = train_mode_config(
            &e, &pool[..n], steps, replicas, accum, false, StepMode::Map, KIB256,
        );
        for bucket_bytes in [1usize, KIB256, usize::MAX] {
            let flat = train_mode_config(
                &e, &pool[..n], steps, replicas, accum, false, StepMode::Flat, bucket_bytes,
            );
            assert_params_bitwise(
                &format!("{replicas}x{accum} flat(bb={bucket_bytes}) vs map"),
                &map_ref,
                &flat,
            );
        }
    }
}

/// The flat engine under the sequential executor still streams
/// gradients through the board/reducer — same bits as everything else.
#[test]
fn flat_step_sequential_executor_matches_map() {
    let e = engine();
    let d = e.dims().clone();
    let pool: Vec<Batch> = (0..4).map(|j| random_batch(&d, 700 + j as u64)).collect();
    let map_ref = train_mode_config(&e, &pool, 2, 2, 1, true, StepMode::Map, KIB256);
    let flat = train_mode_config(&e, &pool, 2, 2, 1, true, StepMode::Flat, KIB256);
    assert_params_bitwise("sequential flat vs map", &map_ref, &flat);
}

/// A mis-sized micro list is an error, not a panic or a silent
/// truncation.
#[test]
fn wrong_micro_count_errors() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let mut tr = Trainer::new(&e, &exp).unwrap();
    tr.set_pipeline(2, 2);
    let batch = random_batch(&d, 7);
    let err = tr.train_step_micro(std::slice::from_ref(&batch)).unwrap_err();
    assert!(err.to_string().contains("micro-batches"), "{err}");
    // train_step is the 1-micro-batch convenience: wrong here too.
    assert!(tr.train_step(&batch).is_err());
}

/// Checkpoint v2 makes resume *exact*: save at step k (after a
/// scheduled eval, so the plateau reference and sim clock are live),
/// restore into a fresh trainer, continue through another eval —
/// bitwise the same parameters, LR and clocks as never stopping.
#[test]
fn v2_resume_is_bitwise_exact() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let pool: Vec<Batch> = (0..4).map(|j| random_batch(&d, 400 + j as u64)).collect();
    let dev = vec![random_batch(&d, 500)];

    let mut full = Trainer::new(&e, &exp).unwrap();
    for b in &pool[..2] {
        full.train_step(b).unwrap();
    }
    full.eval_and_schedule(&dev).unwrap();
    let dir = std::env::temp_dir().join("hynmt_train_eq");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.bin");
    full.save_checkpoint(&path).unwrap();
    let clock_at_save = full.sim_clock();
    for b in &pool[2..] {
        full.train_step(b).unwrap();
    }
    let ev_full = full.eval_and_schedule(&dev).unwrap();

    let mut resumed = Trainer::new(&e, &exp).unwrap();
    resumed.resume(&path).unwrap();
    assert_eq!(resumed.steps_done(), 2);
    assert_eq!(resumed.sim_clock().to_bits(), clock_at_save.to_bits());
    for b in &pool[2..] {
        resumed.train_step(b).unwrap();
    }
    let ev_res = resumed.eval_and_schedule(&dev).unwrap();
    assert_eq!(resumed.steps_done(), full.steps_done());
    assert_params_bitwise("resumed vs continuous", full.params(), resumed.params());
    // The persisted training clocks + plateau reference make the whole
    // schedule continue identically, not just the weights.
    assert_eq!(ev_full.dev_ppl.to_bits(), ev_res.dev_ppl.to_bits(), "dev ppl");
    assert_eq!(ev_full.lr.to_bits(), ev_res.lr.to_bits(), "post-eval LR");
    assert_eq!(ev_full.sim_hours.to_bits(), ev_res.sim_hours.to_bits(), "sim clock");
}

/// Checkpoint v2 through the slab round-trip, across engines: a
/// checkpoint saved by the flat engine (slab params, slab-backed Adam
/// moments) resumes a **map**-engine trainer — and vice versa — and
/// both continuations land on the same bits as never stopping. The
/// on-disk bytes cannot depend on the storage the state lived in.
#[test]
fn v2_checkpoint_round_trips_across_step_engines() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let pool: Vec<Batch> = (0..4).map(|j| random_batch(&d, 800 + j as u64)).collect();
    let dir = std::env::temp_dir().join("hynmt_train_eq");
    std::fs::create_dir_all(&dir).unwrap();

    for (save_mode, resume_mode) in
        [(StepMode::Flat, StepMode::Map), (StepMode::Map, StepMode::Flat)]
    {
        let mut full = Trainer::new(&e, &exp).unwrap();
        full.set_step_mode(save_mode);
        for b in &pool[..2] {
            full.train_step(b).unwrap();
        }
        let path = dir.join(format!("xresume_{save_mode:?}.bin"));
        full.save_checkpoint(&path).unwrap();
        for b in &pool[2..] {
            full.train_step(b).unwrap();
        }

        let mut resumed = Trainer::new(&e, &exp).unwrap();
        resumed.set_step_mode(resume_mode);
        resumed.resume(&path).unwrap();
        assert_eq!(resumed.steps_done(), 2);
        for b in &pool[2..] {
            resumed.train_step(b).unwrap();
        }
        assert_params_bitwise(
            &format!("saved by {save_mode:?}, resumed by {resume_mode:?}"),
            full.params(),
            resumed.params(),
        );
    }
}

// --------------------------------------------------------------------------
// Mixed precision (16-bit slabs + dynamic loss scaling)
// --------------------------------------------------------------------------

/// Train `steps` single-shard steps at the given slab precision and
/// return (final params, per-step stats).
fn train_precision(
    e: &Engine,
    pool: &[Batch],
    steps: usize,
    dtype: SlabDtype,
) -> (BTreeMap<String, Tensor>, Vec<hybridnmt::train::StepStats>) {
    let exp = test_exp(e);
    let mut tr = Trainer::new(e, &exp).unwrap();
    tr.set_precision(dtype).unwrap();
    let mut stats = Vec::new();
    for b in &pool[..steps] {
        stats.push(tr.train_step(b).unwrap());
    }
    (tr.params().clone(), stats)
}

/// `--precision f32` must stay byte-for-byte the pre-precision path:
/// the explicit f32 setting and the default produce identical bits at
/// every replica spread.
#[test]
fn explicit_f32_precision_is_bitwise_default() {
    let e = engine();
    let d = e.dims().clone();
    let steps = 2;
    let pool: Vec<Batch> = (0..steps * 4).map(|j| random_batch(&d, 900 + j as u64)).collect();
    let reference = train_config(&e, &pool, steps, 1, 4, true);
    for (replicas, accum) in [(1, 4), (2, 2), (4, 1)] {
        let exp = test_exp(&e);
        let mut tr = Trainer::new(&e, &exp).unwrap();
        tr.set_precision(SlabDtype::F32).unwrap();
        tr.set_pipeline(replicas, accum);
        let per = tr.pipeline.micro_per_step();
        for s in 0..steps {
            tr.train_step_micro(&pool[s * per..(s + 1) * per]).unwrap();
        }
        assert_params_bitwise(
            &format!("explicit f32 {replicas}x{accum}"),
            &reference,
            tr.params(),
        );
    }
}

/// The 16-bit bounded-divergence gate: five steps at f16/bf16 stay
/// within a small L2-relative distance of the f32 run on the same
/// batches, per-step losses stay within 15% (loss parity), and the
/// final parameters are exactly representable in the storage dtype
/// (the post-apply rounding really ran).
#[test]
fn half_precision_divergence_is_bounded_over_five_steps() {
    let e = engine();
    let d = e.dims().clone();
    let steps = 5;
    let pool: Vec<Batch> = (0..steps).map(|j| random_batch(&d, 1000 + j as u64)).collect();
    let (ref_params, ref_stats) = train_precision(&e, &pool, steps, SlabDtype::F32);
    assert!(ref_stats.iter().all(|s| !s.overflow_skipped), "f32 never skips");

    for dtype in [SlabDtype::F16, SlabDtype::Bf16] {
        let (params, stats) = train_precision(&e, &pool, steps, dtype);
        // Loss parity per step (skipped steps still report the loss of
        // the batches they consumed, so the comparison is total).
        for (i, (s, r)) in stats.iter().zip(&ref_stats).enumerate() {
            assert!(s.loss_per_tok.is_finite(), "{dtype} step {i}: finite loss");
            let rel = (s.loss_per_tok - r.loss_per_tok).abs() / r.loss_per_tok.abs().max(1e-9);
            assert!(
                rel < 0.15,
                "{dtype} step {i}: loss {} vs f32 {} (rel {rel:.4})",
                s.loss_per_tok,
                r.loss_per_tok
            );
        }
        // Bounded parameter divergence: L2-relative over the whole set.
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (name, x) in &ref_params {
            let y = &params[name];
            for (u, v) in x.data().iter().zip(y.data()) {
                assert!(v.is_finite(), "{dtype}: `{name}` stays finite");
                num += ((u - v) as f64).powi(2);
                den += (*u as f64).powi(2);
            }
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 0.15, "{dtype}: L2-relative divergence {rel:.5} vs f32");
        assert!(rel.is_finite(), "{dtype}: divergence finite");
        // Every stored value must survive a round-trip through the
        // storage dtype unchanged — params live in 16-bit.
        for (name, t) in &params {
            for (i, &v) in t.data().iter().enumerate() {
                assert_eq!(
                    dtype.round(v).to_bits(),
                    v.to_bits(),
                    "{dtype}: `{name}`[{i}] = {v} not representable in {dtype}"
                );
            }
        }
    }
}

/// Forced overflow drill: poisoning one step's gradient with Inf must
/// skip that apply (parameters and optimizer state untouched), halve
/// the loss scale, and leave the next step clean at the halved scale.
#[test]
fn forced_overflow_skips_step_and_halves_scale() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let pool: Vec<Batch> = (0..3).map(|j| random_batch(&d, 1100 + j as u64)).collect();

    let mut tr = Trainer::new(&e, &exp).unwrap();
    tr.set_precision(SlabDtype::Bf16).unwrap();
    let st1 = tr.train_step(&pool[0]).unwrap();
    assert!(!st1.overflow_skipped, "clean warmup step");
    let scale1 = st1.loss_scale;
    assert!(scale1 > 1.0, "16-bit mode starts with a real loss scale");
    let params_after_1 = tr.params().clone();

    tr.force_overflow_next = true;
    let st2 = tr.train_step(&pool[1]).unwrap();
    assert!(st2.overflow_skipped, "poisoned step must be skipped");
    assert_eq!(st2.grad_norm, 0.0, "skipped step reports no grad norm");
    assert_eq!(tr.steps_done(), 2, "a skipped step still counts (batches consumed)");
    assert_params_bitwise("params untouched by skipped step", &params_after_1, tr.params());

    let st3 = tr.train_step(&pool[2]).unwrap();
    assert!(!st3.overflow_skipped, "next step is clean again");
    assert_eq!(st3.loss_scale, scale1 / 2.0, "overflow halved the scale");
    let changed = tr
        .params()
        .iter()
        .any(|(n, t)| t.data().iter().zip(params_after_1[n].data()).any(|(a, b)| a != b));
    assert!(changed, "the clean step after the skip applies an update");
}

/// A 16-bit run checkpoints as v3 and resumes bitwise — params, loss
/// scale and clocks — while the map engine refuses such a checkpoint
/// with a typed error.
#[test]
fn bf16_checkpoint_resumes_bitwise_and_map_engine_rejects_it() {
    let e = engine();
    let d = e.dims().clone();
    let exp = test_exp(&e);
    let pool: Vec<Batch> = (0..4).map(|j| random_batch(&d, 1200 + j as u64)).collect();

    let mut full = Trainer::new(&e, &exp).unwrap();
    full.set_precision(SlabDtype::Bf16).unwrap();
    for b in &pool[..2] {
        full.train_step(b).unwrap();
    }
    let dir = std::env::temp_dir().join("hynmt_train_eq");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume_bf16.bin");
    full.save_checkpoint(&path).unwrap();
    for b in &pool[2..] {
        full.train_step(b).unwrap();
    }

    let mut resumed = Trainer::new(&e, &exp).unwrap();
    resumed.resume(&path).unwrap();
    assert_eq!(resumed.precision(), SlabDtype::Bf16, "precision restored from v3");
    for b in &pool[2..] {
        resumed.train_step(b).unwrap();
    }
    assert_params_bitwise("bf16 resumed vs continuous", full.params(), resumed.params());

    let mut map_tr = Trainer::new(&e, &exp).unwrap();
    map_tr.set_step_mode(StepMode::Map);
    let err = map_tr.resume(&path).unwrap_err();
    assert!(
        err.to_string().contains("flat step engine"),
        "map engine must reject a 16-bit checkpoint: {err:#}"
    );
}

// --------------------------------------------------------------------------
// Optimizer-trait parity vs the seed `Optimizer` numerics (engine-free)
// --------------------------------------------------------------------------

/// The seed repo's optimizer, verbatim: one serial BTreeMap walk with
/// per-element f64 math. The trait impls must reproduce it bit-for-bit
/// at every worker count.
struct SeedOptimizer {
    lr: f64,
    cfg: TrainConfig,
    m: BTreeMap<String, Vec<f32>>,
    v: BTreeMap<String, Vec<f32>>,
    t: u64,
}

impl SeedOptimizer {
    fn new(cfg: &TrainConfig) -> Self {
        SeedOptimizer { lr: cfg.lr, cfg: cfg.clone(), m: BTreeMap::new(), v: BTreeMap::new(), t: 0 }
    }

    fn step(
        &mut self,
        params: &mut BTreeMap<String, Tensor>,
        grads: &BTreeMap<String, Tensor>,
    ) -> f64 {
        self.t += 1;
        let mut sq = 0.0f64;
        for g in grads.values() {
            sq += g.sq_norm() as f64;
        }
        let norm = sq.sqrt();
        let clip = if self.cfg.clip_norm > 0.0 && norm > self.cfg.clip_norm {
            self.cfg.clip_norm / norm
        } else {
            1.0
        };
        if self.cfg.sgd {
            for (name, g) in grads {
                let p = params.get_mut(name).expect("param for grad");
                for (w, &gi) in p.data_mut().iter_mut().zip(g.data()) {
                    *w -= (self.lr * clip * gi as f64) as f32;
                }
            }
            return norm;
        }
        let (b1, b2, eps) = (self.cfg.beta1, self.cfg.beta2, self.cfg.eps);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for (name, g) in grads {
            let p = params.get_mut(name).expect("param for grad");
            let m = self.m.entry(name.clone()).or_insert_with(|| vec![0.0; g.numel()]);
            let v = self.v.entry(name.clone()).or_insert_with(|| vec![0.0; g.numel()]);
            for i in 0..g.numel() {
                let gi = (g.data()[i] as f64) * clip;
                m[i] = (b1 * m[i] as f64 + (1.0 - b1) * gi) as f32;
                v[i] = (b2 * v[i] as f64 + (1.0 - b2) * gi * gi) as f32;
                let mhat = m[i] as f64 / bc1;
                let vhat = v[i] as f64 / bc2;
                p.data_mut()[i] -= (self.lr * mhat / (vhat.sqrt() + eps)) as f32;
            }
        }
        norm
    }
}

/// Multi-tensor variant of the quadratic fixture: f(w) = 0.5 Σ ||w||²,
/// grad = w — several parameters so the per-param sharding actually
/// partitions.
fn quad_params(seed: u64) -> BTreeMap<String, Tensor> {
    let mut rng = Rng::new(seed);
    let mut p = BTreeMap::new();
    for (name, n) in [("a_w", 5usize), ("b_w", 1), ("c_w", 9), ("d_w", 2)] {
        let data: Vec<f32> = (0..n).map(|_| rng.uniform(2.0)).collect();
        p.insert(name.to_string(), Tensor::new(vec![n], data));
    }
    p
}

fn grads_of(params: &BTreeMap<String, Tensor>) -> BTreeMap<String, Tensor> {
    params.clone()
}

#[test]
fn optimizer_trait_matches_seed_numerics_bitwise() {
    for sgd in [false, true] {
        let cfg = TrainConfig { sgd, lr: 0.07, clip_norm: 1.5, ..Default::default() };
        for workers in [1usize, 2, 5] {
            let mut seed_opt = SeedOptimizer::new(&cfg);
            let mut seed_params = quad_params(9);
            let mut trait_opt = optim::build(&cfg);
            let mut trait_params = quad_params(9);
            for step in 0..40 {
                let g = grads_of(&seed_params);
                let n_seed = seed_opt.step(&mut seed_params, &g);
                let g2 = grads_of(&trait_params);
                let n_trait = trait_opt.apply(&mut trait_params, &g2, workers).unwrap();
                assert_eq!(
                    n_seed.to_bits(),
                    n_trait.to_bits(),
                    "sgd={sgd} workers={workers} step {step}: grad norm"
                );
            }
            assert_params_bitwise(
                &format!("sgd={sgd} workers={workers}"),
                &seed_params,
                &trait_params,
            );
        }
    }
}

/// The seed panicked on a gradient with no matching parameter; the
/// trait returns an error (satellite: panic→error cleanup).
#[test]
fn optimizer_rejects_unknown_gradient() {
    let cfg = TrainConfig::default();
    let mut opt = optim::build(&cfg);
    let mut params = quad_params(1);
    let mut g = BTreeMap::new();
    g.insert("zz_unknown".to_string(), Tensor::new(vec![2], vec![1.0, 2.0]));
    let err = opt.apply(&mut params, &g, 1).unwrap_err();
    assert!(err.to_string().contains("unknown parameter"), "{err}");
}
