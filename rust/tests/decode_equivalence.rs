//! Decode equivalence: the batched multi-device inference engine must
//! produce *token-identical* translations to N single-sentence
//! `Decoder::translate` calls — across beam widths, chunk sizes and
//! 1/2/4-worker shardings — while uploading each parameter at most once
//! for the life of the bank (requires `make artifacts`).
//!
//! This is the inference counterpart of `exec_equivalence.rs`: packing,
//! device-resident state and sharding may reorder *how* the device is
//! called, never what each sentence's beam search computes.

use hybridnmt::config::{DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig};
use hybridnmt::decode::{
    translate_corpus, BatchDecoder, BeamConfig, DecodeOptions, Decoder, LengthNorm,
};
use hybridnmt::rng::Rng;
use hybridnmt::runtime::{quantize_params, Engine, ParamBank};
use hybridnmt::tensor::Tensor;
use hybridnmt::train::{checkpoint, init_params};
use std::collections::BTreeMap;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

fn random_params(
    d: &ModelDims,
    input_feeding: bool,
    seed: u64,
) -> BTreeMap<String, Tensor> {
    let exp = Experiment {
        model: d.clone(),
        strategy: if input_feeding { Strategy::Single } else { Strategy::Hybrid },
        hw: HwConfig::default(),
        train: TrainConfig { seed, ..Default::default() },
        data: DataConfig::wmt14_sim(100),
        artifacts_dir: "artifacts".into(),
    };
    init_params(&exp, input_feeding)
}

/// Deterministic random source sentences within the artifact shape.
fn random_srcs(d: &ModelDims, n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = rng.range(2, d.max_src + 1);
            (0..len).map(|_| rng.range(4, d.vocab) as i32).collect()
        })
        .collect()
}

fn cfg(beam: usize, max_tgt: usize) -> BeamConfig {
    BeamConfig { beam, max_len: max_tgt, norm: LengthNorm::Marian { alpha: 1.0 } }
}

/// The acceptance criterion: batched decode at every (batch, devices)
/// sharding equals sequential single-sentence decoding, token for
/// token, for beams 1 and 4, with and without input-feeding.
#[test]
fn batched_matches_single_across_beams_and_shardings() {
    let e = engine();
    let d = e.dims().clone();
    let srcs = random_srcs(&d, 10, 42);
    for input_feeding in [false, true] {
        let params = random_params(&d, input_feeding, 3);
        for beam in [1usize, 4] {
            let c = cfg(beam, d.max_tgt);
            let dec = Decoder::new(&e, &params, input_feeding);
            let reference: Vec<Vec<i32>> = srcs
                .iter()
                .map(|s| dec.translate(s, &c).unwrap())
                .collect();
            for (batch, devices) in [(1usize, 1usize), (4, 1), (4, 2), (32, 4)] {
                let bank = ParamBank::new();
                let opts = DecodeOptions { batch, devices };
                let (hyps, stats) =
                    translate_corpus(&e, &params, &bank, input_feeding, &srcs, &c, &opts)
                        .unwrap_or_else(|err| {
                            panic!("if={input_feeding} beam={beam} b={batch} d={devices}: {err:#}")
                        });
                assert_eq!(stats.sentences, srcs.len());
                for (i, (h, r)) in hyps.iter().zip(&reference).enumerate() {
                    assert_eq!(
                        h, r,
                        "if={input_feeding} beam={beam} batch={batch} devices={devices}: \
                         sentence {i} diverged"
                    );
                }
            }
        }
    }
}

/// Each parameter crosses the host→device boundary at most once per
/// bank lifetime, however many sentences/workers the corpus run uses.
#[test]
fn params_upload_once_per_bank_lifetime() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, false, 7);
    let srcs = random_srcs(&d, 8, 9);
    let bank = ParamBank::new();
    let c = cfg(4, d.max_tgt);
    let opts = DecodeOptions { batch: 4, devices: 2 };
    let (_, cold) = translate_corpus(&e, &params, &bank, false, &srcs, &c, &opts).unwrap();
    assert_eq!(
        bank.upload_count() as usize,
        params.len(),
        "cold run must upload each parameter exactly once"
    );
    assert!(cold.param_hits > 0, "cold run should already hit the bank");
    // The bank is never invalidated by decoding: a second pass is free.
    let (_, warm) = translate_corpus(&e, &params, &bank, false, &srcs, &c, &opts).unwrap();
    assert_eq!(warm.param_uploads, 0, "warm corpus run re-uploaded parameters");
    // Encoder state is uploaded once per group and served resident on
    // every decode step thereafter.
    assert!(warm.state_hits >= warm.state_uploads);
}

/// `load_resident` pre-uploads the checkpoint: the first decode step
/// finds every weight already on device, and the loaded parameters
/// decode identically to the in-memory set they were saved from.
#[test]
fn resident_checkpoint_decodes_identically_with_zero_uploads() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, false, 11);
    let dir = std::env::temp_dir().join("hynmt_decode_eq");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");
    checkpoint::save(&path, &params).unwrap();
    let (loaded, bank) = checkpoint::load_resident(&path, &e).unwrap();
    assert_eq!(bank.upload_count() as usize, loaded.len());

    let srcs = random_srcs(&d, 4, 13);
    let c = cfg(4, d.max_tgt);
    let opts = DecodeOptions { batch: 4, devices: 1 };
    let (hyps, stats) =
        translate_corpus(&e, &loaded, &bank, false, &srcs, &c, &opts).unwrap();
    assert_eq!(stats.param_uploads, 0, "resident checkpoint re-uploaded parameters");

    let fresh = ParamBank::new();
    let (reference, _) =
        translate_corpus(&e, &params, &fresh, false, &srcs, &c, &opts).unwrap();
    assert_eq!(hyps, reference);
}

/// Oversize / empty sources and absurd beams are errors, not silent
/// truncation or panics — on both decode paths.
#[test]
fn invalid_inputs_error_cleanly() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, false, 5);
    let c = cfg(2, d.max_tgt);

    let dec = Decoder::new(&e, &params, false);
    let long = vec![5i32; d.max_src + 1];
    assert!(dec.translate(&long, &c).is_err(), "oversize source must error");
    assert!(dec.translate(&[], &c).is_err(), "empty source must error");
    assert!(
        dec.translate(&[5, 6], &cfg(d.beam + 1, d.max_tgt)).is_err(),
        "beam wider than the artifact width must error"
    );

    let bank = ParamBank::new();
    let bd = BatchDecoder::new(&e, &params, &bank, false).unwrap();
    assert!(bd.translate_batch(&[long.clone()], &c).is_err());
    assert!(bd.translate_batch(&[vec![]], &c).is_err());
    assert!(bd
        .translate_batch(&[vec![5, 6]], &cfg(bd.width() + 1, d.max_tgt))
        .is_err());
    // A good sentence after a bad one: the whole batch is rejected
    // before any device work happens.
    assert!(bd.translate_batch(&[vec![5, 6], long], &c).is_err());
}

/// Int8 dequant-on-bind is constructionally exact: a quantized bank
/// decodes token-identically to decoding with the host-dequantized
/// tensors through a plain f32 bank (same expanded weights either
/// way), while the bank's traffic accounting reports the i8 bytes —
/// a ~4× reduction over the f32 baseline.
#[test]
fn int8_bank_decodes_via_dequantized_weights_with_quarter_uploads() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, false, 17);
    let srcs = random_srcs(&d, 6, 19);
    let c = cfg(4, d.max_tgt);
    let opts = DecodeOptions { batch: 4, devices: 2 };

    let q = std::sync::Arc::new(quantize_params(&params));
    assert_eq!(q.len(), params.len());
    let deq: BTreeMap<String, Tensor> = params
        .keys()
        .map(|k| (k.clone(), q.get(k).unwrap().dequantize()))
        .collect();
    let fresh = ParamBank::new();
    let (ref_hyps, _) =
        translate_corpus(&e, &deq, &fresh, false, &srcs, &c, &opts).unwrap();

    let qbank = ParamBank::new();
    qbank.set_quantized(q.clone());
    assert_eq!(qbank.quant_kind(), Some("int8"));
    // The caller still passes the original f32 params: the bank ignores
    // their values (name/shape contract only) and binds dequantized int8.
    let (q_hyps, q_stats) =
        translate_corpus(&e, &params, &qbank, false, &srcs, &c, &opts).unwrap();
    assert_eq!(
        q_hyps, ref_hyps,
        "dequant-on-bind must serve exactly the dequantized weights"
    );

    // Byte accounting: every parameter bound once, each recorded at its
    // i8 size (payload + 4-byte scale) — strictly under a third of f32.
    assert_eq!(q_stats.param_bytes_uploaded, q.total_bytes());
    assert!(
        q.total_bytes() < q.f32_bytes() / 3,
        "int8 uploads {} not ~4x under f32 {}",
        q.total_bytes(),
        q.f32_bytes()
    );
}

/// The serve-bench acceptance gate (`--quantize int8` token-delta vs
/// the f32 reference) at its fixed point: weights already on the int8
/// grid — built with a power-of-two scale so every value and the scale
/// itself are exactly representable — requantize bit-for-bit, and the
/// quantized decode shows an accept delta of exactly 0.
#[test]
fn int8_is_exact_on_grid_snapped_weights() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, false, 23);
    let snapped: BTreeMap<String, Tensor> = {
        let q0 = quantize_params(&params);
        params
            .keys()
            .map(|k| {
                let qt = q0.get(k).unwrap();
                // 2^-10 keeps magnitudes near the init scale; being a
                // power of two makes `max_abs / 127` round-trip exact.
                let data: Vec<f32> =
                    qt.data.iter().map(|&v| v as f32 * 0.0009765625).collect();
                (k.clone(), Tensor::new(qt.shape.clone(), data))
            })
            .collect()
    };
    // Requantization of on-grid weights is the identity.
    let q = quantize_params(&snapped);
    for (k, t) in &snapped {
        let qt = q.get(k).unwrap();
        let rt = qt.dequantize();
        assert_eq!(rt.data(), t.data(), "`{k}` not a quantization fixed point");
    }

    let srcs = random_srcs(&d, 6, 29);
    let c = cfg(4, d.max_tgt);
    let opts = DecodeOptions { batch: 4, devices: 1 };
    let fresh = ParamBank::new();
    let (ref_hyps, _) =
        translate_corpus(&e, &snapped, &fresh, false, &srcs, &c, &opts).unwrap();
    let qbank = ParamBank::new();
    qbank.set_quantized(std::sync::Arc::new(q));
    let (q_hyps, _) =
        translate_corpus(&e, &snapped, &qbank, false, &srcs, &c, &opts).unwrap();
    let differing = q_hyps.iter().zip(&ref_hyps).filter(|(h, r)| h != r).count();
    assert_eq!(differing, 0, "on-grid weights must decode with zero token delta");
}

/// The packed width really is wider than the single-sentence path's
/// beam width (otherwise batching buys nothing on this artifact set).
#[test]
fn packed_width_exceeds_beam_width() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, false, 1);
    let bank = ParamBank::new();
    let bd = BatchDecoder::new(&e, &params, &bank, false).unwrap();
    assert!(bd.width() >= d.batch, "expected the training-batch artifacts");
    assert!(bd.group_capacity(1) > 1);
    assert_eq!(bd.group_capacity(4), bd.width() / 4);
}
