//! Serving equivalence: the online scheduler (bounded admission →
//! length-bucketed coalescer → multi-replica dispatch) must return,
//! for every request, the *exact* tokens the single-sentence reference
//! `Decoder` produces — across beam widths, replica counts and arrival
//! orders — while mapping every response to the right request id,
//! shedding overload with a clean error, and reporting the serving
//! metrics `BENCH_serve.json` tracks (requires `make artifacts`).
//!
//! This is the serving counterpart of `decode_equivalence.rs`: arrival
//! timing, coalescing and replica scheduling may reorder *when* and
//! *with whom* a sentence is decoded, never what it decodes to.

use hybridnmt::config::{DataConfig, Experiment, HwConfig, ModelDims, Strategy, TrainConfig};
use hybridnmt::decode::{BeamConfig, Decoder, LengthNorm};
use hybridnmt::report::{serve_table, ServeRow};
use hybridnmt::rng::Rng;
use hybridnmt::runtime::{Engine, ParamBank};
use hybridnmt::serve::{
    drive_arrivals, poisson_arrivals, run_server, ServeOptions, SubmitError,
};
use hybridnmt::tensor::Tensor;
use hybridnmt::train::init_params;
use hybridnmt::util::json::Json;
use std::collections::BTreeMap;

fn engine() -> Engine {
    Engine::load("artifacts", "tiny").expect("run `make artifacts` first")
}

fn random_params(d: &ModelDims, seed: u64) -> BTreeMap<String, Tensor> {
    let exp = Experiment {
        model: d.clone(),
        strategy: Strategy::Hybrid,
        hw: HwConfig::default(),
        train: TrainConfig { seed, ..Default::default() },
        data: DataConfig::wmt14_sim(100),
        artifacts_dir: "artifacts".into(),
    };
    init_params(&exp, false)
}

/// Deterministic random source sentences within the artifact shape.
fn random_srcs(d: &ModelDims, n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = rng.range(2, d.max_src + 1);
            (0..len).map(|_| rng.range(4, d.vocab) as i32).collect()
        })
        .collect()
}

fn cfg(beam: usize, max_tgt: usize) -> BeamConfig {
    BeamConfig { beam, max_len: max_tgt, norm: LengthNorm::Marian { alpha: 1.0 } }
}

/// The acceptance criterion: for beams {1, 4} × replicas {1, 2, 4} ×
/// two arrival seeds, every served request's tokens equal the
/// single-sentence reference and responses carry the right ids.
#[test]
fn served_tokens_match_reference_across_beams_replicas_seeds() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 3);
    let bank = ParamBank::new();
    let pool = random_srcs(&d, 10, 42);
    for beam in [1usize, 4] {
        let c = cfg(beam, d.max_tgt);
        let dec = Decoder::new(&e, &params, false);
        let reference: Vec<Vec<i32>> =
            pool.iter().map(|s| dec.translate(s, &c).unwrap()).collect();
        for replicas in [1usize, 2, 4] {
            for seed in [11u64, 23] {
                // Fast Poisson arrivals: timing-noisy, token-exact.
                let arrivals = poisson_arrivals(&pool, 16, 400.0, seed);
                let opts = ServeOptions { replicas, queue_capacity: 64, ..Default::default() };
                let (drive, responses, stats) =
                    run_server(&e, &params, &bank, false, &c, &opts, |h| {
                        drive_arrivals(h, &arrivals)
                    })
                    .unwrap_or_else(|err| {
                        panic!("beam={beam} replicas={replicas} seed={seed}: {err:#}")
                    });
                assert_eq!(drive.rejected, 0, "capacity 64 must admit all 16");
                assert_eq!(responses.len(), arrivals.len());
                assert_eq!(stats.completed, arrivals.len() as u64);
                for (resp, arr) in responses.iter().zip(&arrivals) {
                    // Sorted by id == schedule order: ids map back to
                    // the arrivals they were submitted under.
                    assert_eq!(resp.id, arr.id);
                    assert_eq!(
                        resp.tokens,
                        reference[resp.id as usize % pool.len()],
                        "beam={beam} replicas={replicas} seed={seed}: request {} diverged",
                        resp.id
                    );
                    assert!(resp.latency_s >= 0.0 && resp.latency_s.is_finite());
                }
            }
        }
    }
}

/// Two opposite arrival orders of the same request set produce the
/// same id → tokens mapping: coalescing is order-insensitive where it
/// matters.
#[test]
fn arrival_permutation_preserves_tokens() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 5);
    let bank = ParamBank::new();
    let pool = random_srcs(&d, 8, 7);
    let c = cfg(4, d.max_tgt);
    let opts = ServeOptions { replicas: 2, queue_capacity: 64, ..Default::default() };
    let mut runs: Vec<Vec<(u64, Vec<i32>)>> = Vec::new();
    for reverse in [false, true] {
        let mut ids: Vec<u64> = (0..pool.len() as u64).collect();
        if reverse {
            ids.reverse();
        }
        let (_, responses, _) = run_server(&e, &params, &bank, false, &c, &opts, |h| {
            for &i in &ids {
                h.submit(i, pool[i as usize].clone()).expect("capacity 64 admits all");
            }
            Ok(())
        })
        .unwrap();
        runs.push(responses.into_iter().map(|r| (r.id, r.tokens)).collect());
    }
    assert_eq!(runs[0], runs[1], "arrival order changed some request's tokens");
}

/// Admission control: a burst far over the in-flight bound is shed
/// with `SubmitError::QueueFull` — a clean error, not a panic and not
/// an unbounded queue — and everything admitted still completes and
/// matches the reference.
#[test]
fn queue_full_sheds_cleanly() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 9);
    let bank = ParamBank::new();
    let pool = random_srcs(&d, 6, 13);
    let c = cfg(4, d.max_tgt);
    let dec = Decoder::new(&e, &params, false);
    let reference: Vec<Vec<i32>> =
        pool.iter().map(|s| dec.translate(s, &c).unwrap()).collect();
    let opts = ServeOptions { replicas: 1, queue_capacity: 2, ..Default::default() };
    let (shed, responses, stats) = run_server(&e, &params, &bank, false, &c, &opts, |h| {
        // 32 instant submissions against an in-flight bound of 2: the
        // decode of the first admissions is still running, so most of
        // the burst must be refused.
        let mut shed = 0u64;
        for i in 0..32u64 {
            match h.submit(i, pool[i as usize % pool.len()].clone()) {
                Ok(()) => {}
                Err(SubmitError::QueueFull { capacity }) => {
                    assert_eq!(capacity, 2);
                    shed += 1;
                }
                Err(other) => panic!("unexpected submit error: {other}"),
            }
        }
        Ok(shed)
    })
    .unwrap();
    assert!(shed > 0, "a 32-burst against capacity 2 must shed");
    assert_eq!(stats.rejected, shed);
    assert_eq!(stats.accepted + stats.rejected, stats.submitted);
    assert_eq!(responses.len() as u64, stats.accepted, "every admitted request completes");
    for resp in &responses {
        assert_eq!(resp.tokens, reference[resp.id as usize % pool.len()]);
    }
    // Oversize and empty sources are refused at admission and counted
    // separately from backpressure sheds — malformed input must never
    // read as queue pressure (and never panic a replica).
    let (_, _, stats) = run_server(&e, &params, &bank, false, &c, &opts, |h| {
        assert!(matches!(
            h.submit(0, vec![5; d.max_src + 1]),
            Err(SubmitError::Invalid(_))
        ));
        assert!(matches!(h.submit(1, vec![]), Err(SubmitError::Invalid(_))));
        Ok(())
    })
    .unwrap();
    assert_eq!(stats.invalid, 2);
    assert_eq!(stats.rejected, 0, "invalid input must not count as backpressure");
    assert_eq!(stats.completed, 0);
}

/// Hardening: a panic inside a replica thread must surface as
/// `run_server`'s typed error carrying the panic payload — a clean
/// drain and a readable message, never a process abort (an unwinding
/// scoped thread would otherwise take down the whole test binary) and
/// never a hang.
#[test]
fn replica_panic_surfaces_as_typed_error_not_abort() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 21);
    let bank = ParamBank::new();
    let pool = random_srcs(&d, 4, 31);
    let c = cfg(1, d.max_tgt);
    let opts = ServeOptions {
        replicas: 2,
        queue_capacity: 64,
        panic_replica_at: Some(1),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let err = run_server(&e, &params, &bank, false, &c, &opts, |h| {
        for (i, s) in pool.iter().enumerate() {
            // The injected panic may close submissions mid-burst; that
            // shutdown race is exactly what the drain must tolerate.
            let _ = h.submit(i as u64, s.clone());
        }
        Ok(())
    })
    .expect_err("an injected replica panic must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("panicked"), "error must name the panic: {msg}");
    assert!(
        msg.contains("injected replica panic"),
        "panic payload must survive into the typed error: {msg}"
    );
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(60),
        "a replica panic must drain promptly, not hang"
    );
}

/// The serving benchmark artifact: `serve_table` must emit a
/// `BENCH_serve.json` whose rows carry p50/p95/p99 latency, batch-fill
/// ratio and sustained sentences/sec as finite numbers.
#[test]
fn bench_serve_json_reports_percentiles_fill_and_throughput() {
    let e = engine();
    let d = e.dims().clone();
    let params = random_params(&d, 17);
    let bank = ParamBank::new();
    let pool = random_srcs(&d, 6, 19);
    let c = cfg(4, d.max_tgt);
    let arrivals = poisson_arrivals(&pool, 12, 300.0, 29);
    let mut rows = Vec::new();
    for replicas in [1usize, 2] {
        let opts = ServeOptions { replicas, queue_capacity: 64, ..Default::default() };
        let (drive, _, stats) = run_server(&e, &params, &bank, false, &c, &opts, |h| {
            drive_arrivals(h, &arrivals)
        })
        .unwrap();
        assert!(stats.mean_fill() > 0.0, "groups must report a fill ratio");
        assert!(stats.sentences_per_sec() > 0.0);
        rows.push(ServeRow { replicas, beam: 4, offered_per_s: drive.offered_per_s, stats });
    }
    let out = serve_table(&rows);
    assert!(out.contains("p50"), "table must show tail latency columns");
    let text = std::fs::read_to_string("BENCH_serve.json").unwrap();
    let json = Json::parse(&text).unwrap();
    let obj = json.as_obj().unwrap();
    for suffix in ["p50_ms", "p95_ms", "p99_ms", "sent_per_s", "batch_fill"] {
        for replicas in [1usize, 2] {
            let prefix = format!("r{replicas}.beam4.");
            let found = obj.iter().any(|(k, v)| {
                k.starts_with(&prefix)
                    && k.ends_with(suffix)
                    && v.as_f64().is_some_and(f64::is_finite)
            });
            assert!(found, "BENCH_serve.json missing finite `{prefix}*.{suffix}`");
        }
    }
}
