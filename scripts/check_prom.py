#!/usr/bin/env python3
"""Validate a Prometheus text-exposition (0.0.4) dump.

    python3 scripts/check_prom.py results/metrics.prom [required_family ...]

Checks, in order of increasing specificity:

  * every line is a comment (`# HELP` / `# TYPE`), blank, or a sample
    `name{labels} value` with a valid metric name, well-formed quoted
    label values, and a parseable value;
  * every sample belongs to a family declared by a preceding `# TYPE`
    (histogram `_bucket`/`_sum`/`_count` suffixes resolve to their base
    family), and no family is declared twice;
  * counter samples are finite and non-negative;
  * histogram families are structurally sound per label set: buckets
    are cumulative (non-decreasing in `le`), end at `le="+Inf"`, and
    agree with the `_count` sample; `_sum` and `_count` are present;
  * each `required_family` argument names a family that must be present
    with at least one sample (the acceptance hook: verify.sh requires
    the serve / coalesce / loadgen counters and the HLL-backed
    distinct-users gauge).

Exit status 0 on success; 1 with a diagnostic on the first failure.
"""

import math
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
KINDS = {"counter", "gauge", "histogram", "summary", "untyped"}


PATH = "metrics.prom"


def die(lineno, msg):
    raise SystemExit(f"{PATH}:{lineno}: {msg}")


def parse_value(tok, lineno):
    if tok == "+Inf":
        return math.inf
    if tok == "-Inf":
        return -math.inf
    if tok == "NaN":
        return math.nan
    try:
        return float(tok)
    except ValueError:
        die(lineno, f"unparseable sample value `{tok}`")


def parse_labels(text, lineno):
    """`a="x",b="y"` (no braces) -> dict. Handles \\\\, \\" and \\n."""
    labels = {}
    i = 0
    while i < len(text):
        eq = text.find("=", i)
        if eq < 0:
            die(lineno, f"malformed label segment `{text[i:]}`")
        name = text[i:eq]
        if not LABEL_NAME_RE.match(name):
            die(lineno, f"invalid label name `{name}`")
        if eq + 1 >= len(text) or text[eq + 1] != '"':
            die(lineno, f"label `{name}` value is not quoted")
        j = eq + 2
        val = []
        while j < len(text):
            c = text[j]
            if c == "\\":
                if j + 1 >= len(text):
                    die(lineno, f"dangling escape in label `{name}`")
                esc = text[j + 1]
                val.append({"\\": "\\", '"': '"', "n": "\n"}.get(esc) or
                           die(lineno, f"bad escape `\\{esc}` in label `{name}`"))
                j += 2
            elif c == '"':
                break
            else:
                val.append(c)
                j += 1
        else:
            die(lineno, f"unterminated label value for `{name}`")
        if name in labels:
            die(lineno, f"duplicate label `{name}`")
        labels[name] = "".join(val)
        i = j + 1
        if i < len(text):
            if text[i] != ",":
                die(lineno, f"expected `,` between labels, got `{text[i]}`")
            i += 1
    return labels


def main():
    if len(sys.argv) < 2:
        raise SystemExit(__doc__)
    global PATH
    path, required = sys.argv[1], sys.argv[2:]
    PATH = path
    with open(path) as fh:
        lines = fh.read().splitlines()

    types = {}          # family -> kind
    samples = []        # (family, suffix, labels, value, lineno)
    n_samples = 0
    for lineno, line in enumerate(lines, 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                continue  # free-form comment — legal
            fam = parts[2]
            if not NAME_RE.match(fam):
                die(lineno, f"invalid family name `{fam}` in {parts[1]}")
            if parts[1] == "TYPE":
                kind = parts[3].strip() if len(parts) > 3 else ""
                if kind not in KINDS:
                    die(lineno, f"unknown metric type `{kind}`")
                if fam in types:
                    die(lineno, f"family `{fam}` declared twice")
                types[fam] = kind
            continue

        # Sample line: name[{labels}] value
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})?\s+(\S+)$", line)
        if not m:
            die(lineno, f"malformed sample line `{line}`")
        name, labels_text, value_tok = m.group(1), m.group(3), m.group(4)
        labels = parse_labels(labels_text, lineno) if labels_text else {}
        value = parse_value(value_tok, lineno)
        n_samples += 1

        # Resolve the family: exact, or histogram series suffixes.
        fam, suffix = name, ""
        if name not in types:
            for s in ("_bucket", "_sum", "_count"):
                base = name[: -len(s)] if name.endswith(s) else None
                if base and types.get(base) == "histogram":
                    fam, suffix = base, s
                    break
            else:
                die(lineno, f"sample `{name}` has no preceding # TYPE")
        kind = types[fam]
        if kind == "histogram" and not suffix:
            die(lineno, f"bare sample for histogram family `{fam}`")
        if kind == "counter" and not (value >= 0 and math.isfinite(value)):
            die(lineno, f"counter `{name}` has non-finite/negative value {value_tok}")
        samples.append((fam, suffix, labels, value, lineno))

    if n_samples == 0:
        raise SystemExit(f"{path}: no samples at all")

    # Histogram structure per (family, label-set-without-le).
    hists = {}
    for fam, suffix, labels, value, lineno in samples:
        if types[fam] != "histogram":
            continue
        base = tuple(sorted((k, v) for k, v in labels.items() if k != "le"))
        h = hists.setdefault((fam, base), {"buckets": [], "sum": None, "count": None})
        if suffix == "_bucket":
            if "le" not in labels:
                die(lineno, f"`{fam}_bucket` without an `le` label")
            h["buckets"].append((parse_value(labels["le"], lineno), value, lineno))
        elif suffix == "_sum":
            h["sum"] = value
        elif suffix == "_count":
            h["count"] = (value, lineno)

    for (fam, base), h in sorted(hists.items()):
        where = f"histogram `{fam}` {dict(base)}"
        if not h["buckets"]:
            raise SystemExit(f"{path}: {where}: no _bucket samples")
        if h["sum"] is None or h["count"] is None:
            raise SystemExit(f"{path}: {where}: missing _sum or _count")
        bs = sorted(h["buckets"], key=lambda t: t[0])
        if not math.isinf(bs[-1][0]):
            raise SystemExit(f"{path}: {where}: no le=\"+Inf\" bucket")
        prev = -1.0
        for le, cum, lineno in bs:
            if cum < prev:
                die(lineno, f"{where}: bucket le={le} count {cum} < previous {prev} "
                            "(buckets must be cumulative)")
            prev = cum
        if bs[-1][1] != h["count"][0]:
            raise SystemExit(f"{path}: {where}: +Inf bucket {bs[-1][1]} != _count "
                             f"{h['count'][0]}")

    present = {fam for fam, _, _, _, _ in samples}
    missing = [r for r in required if r not in present]
    if missing:
        raise SystemExit(f"{path}: required metric families absent: {missing} "
                         f"(have {len(present)} families)")

    print(f"  {path}: exposition format OK "
          f"({len(types)} families, {n_samples} samples, {len(hists)} histogram series)")


if __name__ == "__main__":
    main()
