#!/usr/bin/env python3
"""Structural balance lint for rust sources, no toolchain required.

Walks every ``*.rs`` file under the given roots and checks that braces,
brackets and parentheses balance after stripping line comments, (nested)
block comments, double-quoted strings (with escapes), raw strings
(``r".."``/``r#".."#``, optionally byte-prefixed), char literals and
lifetimes. This is the promotion of the ad-hoc check earlier PRs ran by
hand into a first-class ``scripts/verify.sh`` stage: it catches the
classic editing accidents (a dropped ``}`` in a 700-line file, an extra
paren from a half-applied diff) on machines where ``cargo build`` cannot
run at all.

Exit status: 0 when every file balances, 1 otherwise (one diagnostic
line per problem).
"""

import pathlib
import sys

OPEN = {"{": "{", "[": "[", "(": "("}
CLOSE = {"}": "{", "]": "[", ")": "("}


def balance_errors(path: pathlib.Path) -> list:
    src = path.read_text(encoding="utf-8", errors="replace")
    i, n = 0, len(src)
    depth = {"{": 0, "[": 0, "(": 0}
    line = 1
    errs = []
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if src.startswith("//", i):
            j = src.find("\n", i)
            i = n if j == -1 else j
            continue
        if src.startswith("/*", i):  # rust block comments nest
            d = 1
            i += 2
            while i < n and d:
                if src.startswith("/*", i):
                    d += 1
                    i += 2
                elif src.startswith("*/", i):
                    d -= 1
                    i += 2
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            continue
        if c in "rb":  # raw strings: r"..", r#"..."#, br".."
            j = i + 1 if c == "b" else i
            if j < n and src[j] == "r":
                j += 1
                hashes = 0
                while j < n and src[j] == "#":
                    hashes += 1
                    j += 1
                if j < n and src[j] == '"':
                    end = '"' + "#" * hashes
                    k = src.find(end, j + 1)
                    if k == -1:
                        errs.append(f"{path}:{line}: unterminated raw string")
                        return errs
                    line += src.count("\n", i, k)
                    i = k + len(end)
                    continue
        if c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == '"':
                    break
                if src[j] == "\n":
                    line += 1
                j += 1
            if j >= n:
                errs.append(f"{path}:{line}: unterminated string")
                return errs
            i = j + 1
            continue
        if c == "'":
            # Char literal ('x', '\n', '\u{1F600}') vs lifetime ('a).
            if i + 1 < n and src[i + 1] == "\\":
                j = i + 2
                if j < n and src[j] == "u" and j + 1 < n and src[j + 1] == "{":
                    k = src.find("}", j)
                    j = (k + 1) if k != -1 else j + 1
                elif j < n and src[j] == "x":
                    j += 3
                else:
                    j += 1
                i = (j + 1) if (j < n and src[j] == "'") else i + 1
                continue
            if i + 2 < n and src[i + 2] == "'" and src[i + 1] != "'":
                i += 3
                continue
            i += 1  # lifetime / label: just the quote
            continue
        if c in OPEN:
            depth[c] += 1
        elif c in CLOSE:
            want = CLOSE[c]
            depth[want] -= 1
            if depth[want] < 0:
                errs.append(f"{path}:{line}: unbalanced `{c}`")
                depth[want] = 0
        i += 1
    for k, v in depth.items():
        if v != 0:
            errs.append(f"{path}: {v:+d} unbalanced `{k}`")
    return errs


def main(argv: list) -> int:
    roots = argv or ["rust/src", "rust/tests", "benches", "examples"]
    files = []
    for root in roots:
        p = pathlib.Path(root)
        if p.is_file() and p.suffix == ".rs":
            files.append(p)
        else:
            files.extend(sorted(p.rglob("*.rs")))
    if not files:
        print(f"brace-balance: no .rs files under {roots}", file=sys.stderr)
        return 1
    bad = 0
    for f in files:
        for e in balance_errors(f):
            print(e, file=sys.stderr)
            bad += 1
    print(f"brace-balance: {len(files)} files checked, {bad} problems")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
