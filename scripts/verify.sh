#!/bin/sh
# Verification gate: lint + build + tests + rustdoc + BENCH_*.json
# sanity.
#
#   ./scripts/verify.sh            # everything the machine can run
#   SKIP_CARGO=1 ./scripts/verify.sh   # lint + bench-JSON checks only
#
# The brace-balance lint stage needs only python3 and runs
# unconditionally (also available standalone as `make lint`).
#
# The cargo stages run `cargo build --release`, `cargo test -q` (the
# tier-1 gate) and `cargo doc --no-deps` with warnings denied, so docs
# can't silently rot. The JSON stage validates every BENCH_*.json perf
# snapshot (micro/table3/decode) still parses and contains numbers, so
# benches can't silently rot either. On machines without a rust
# toolchain the cargo stages are reported as skipped and the script
# still fails on malformed bench files.

set -eu
cd "$(dirname "$0")/.."

fail=0

# No-toolchain lint: structural brace/bracket/paren balance of every
# rust source. Runs first and everywhere — including machines without
# cargo — so a truncated edit can never land silently.
echo "== brace-balance lint (scripts/brace_balance.py)"
if python3 scripts/brace_balance.py rust/src rust/tests benches examples; then
    :
else
    fail=1
fi

if [ "${SKIP_CARGO:-0}" != "1" ] && command -v cargo >/dev/null 2>&1; then
    echo "== cargo build --release"
    cargo build --release
    echo "== cargo test -q"
    cargo test -q
    echo "== cargo doc --no-deps (warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
else
    echo "== cargo not available (or SKIP_CARGO=1): skipping build/test/doc stages"
fi

echo "== BENCH_*.json sanity"
found=0
for f in BENCH_*.json; do
    [ -e "$f" ] || continue
    found=1
    if python3 - "$f" <<'EOF'
import json, math, re, sys
path = sys.argv[1]
with open(path) as fh:
    data = json.load(fh)
if not isinstance(data, dict) or not data:
    raise SystemExit(f"{path}: expected a non-empty object")
bad = [k for k, v in data.items()
       if not isinstance(v, (int, float)) or not math.isfinite(v)]
if bad:
    raise SystemExit(f"{path}: non-numeric/non-finite entries: {bad[:5]}")
if path.endswith("BENCH_train.json"):
    # The training benchmark's fixed row schema: every row prefix
    # (r<replicas>.accum<K> for the flat engine, r<R>.accum<K>.map for
    # the map reference) must report token throughput, the per-step
    # wall time, the reduce/apply/stall phase breakdown, the per-step
    # parameter-upload count, the share of the reduce hidden under
    # compute (overlap_pct), the f32 allocation churn
    # (allocs_per_step), and the async-checkpoint columns: the
    # training-thread stall per step (checkpoint_stall_ms, ~0 under
    # copy-on-write snapshots — that's the claim) and the background
    # writer bandwidth (checkpoint_bytes_per_s). Since mixed-precision
    # training every row also carries its slab dtype (precision:
    # 0=f32, 1=f16, 2=bf16 — 16-bit rows additionally get a
    # .f16/.bf16 key suffix so f32 keys stay byte-stable), the grad
    # wire traffic (bytes_per_step — the column the 16-bit modes are
    # supposed to halve) and the dynamic-loss-scale skip count
    # (overflow_skips). A train-bench run that stopped writing any of
    # these is a regression, not a formatting choice.
    required = ["tok_per_s", "step_ms", "reduce_ms", "overlap_pct",
                "apply_ms", "stall_ms", "uploads_per_step",
                "allocs_per_step", "checkpoint_stall_ms",
                "checkpoint_bytes_per_s", "precision", "bytes_per_step",
                "overflow_skips"]
    prefixes = {k.rsplit(".", 1)[0] for k in data}
    if not prefixes:
        raise SystemExit(f"{path}: no train rows")
    # Distributed rows (train-bench --dist) have their own fixed key
    # shape: r<replicas>.dist<world>.<ps|replicated>, optionally with a
    # .f16/.bf16 dtype suffix and/or a .chaos suffix (train-bench
    # --chaos: the world ran under the elastic supervisor with scripted
    # rank kills). Anything else containing ".dist" is a malformed row,
    # not a new convention.
    dist_re = re.compile(
        r"^r\d+\.dist\d+\.(ps|replicated)(\.(f16|bf16))?(\.chaos)?$")
    # Chaos rows must additionally price their recovery: relaunches
    # performed, wall-clock added by failures + backoff, and optimizer
    # steps of lost progress re-run after resume. A chaos row without
    # them is a supervised run that stopped reporting what it cost.
    chaos_required = ["restarts", "recovery_ms", "lost_steps"]
    for p in sorted(prefixes):
        if ".dist" in p and not dist_re.match(p):
            raise SystemExit(f"{path}: malformed dist row `{p}` "
                             "(want r<R>.dist<N>.<ps|replicated>"
                             "[.<f16|bf16>][.chaos])")
        if p.endswith(".chaos") and ".dist" not in p:
            raise SystemExit(f"{path}: chaos row `{p}` outside a dist "
                             "world (supervision is a dist feature)")
        row_required = list(required)
        if p.endswith(".chaos"):
            row_required += chaos_required
        missing = [s for s in row_required if f"{p}.{s}" not in data]
        if missing:
            raise SystemExit(f"{path}: row `{p}` missing {missing}")
        if data[f"{p}.precision"] not in (0, 1, 2):
            raise SystemExit(f"{path}: row `{p}` has precision "
                             f"{data[f'{p}.precision']} (want 0=f32, 1=f16, "
                             "2=bf16)")
        if p.endswith(".chaos") and data[f"{p}.restarts"] < 0:
            raise SystemExit(f"{path}: chaos row `{p}` has negative "
                             "restarts")
    dist_rows = sum(1 for p in prefixes if ".dist" in p)
    chaos_rows = sum(1 for p in prefixes if p.endswith(".chaos"))
    print(f"  {path}: train schema OK ({len(prefixes)} rows, "
          f"{dist_rows} dist, {chaos_rows} chaos)")
if path.endswith("BENCH_decode.json"):
    # Decode-bench rows: single.beam<B> (reference path),
    # batch<N>.devices<D>.beam<B> (f32 batched) and
    # int8.batch<N>.devices<D>.beam<B> (quantized sweeps from
    # serve-bench --quantize int8). Every row carries throughput plus
    # the quantization triple: quant (weight bit-width, 0 = f32,
    # 8 = int8), bytes_uploaded (parameter bytes crossing the
    # host→device boundary — the column int8 is supposed to quarter)
    # and accept_delta (fraction of sentences whose tokens differ from
    # the f32 reference; 0 on every f32 row by definition).
    required = ["sent_per_s", "wall_ns", "quant", "bytes_uploaded",
                "accept_delta"]
    prefixes = {k.rsplit(".", 1)[0] for k in data}
    if not prefixes:
        raise SystemExit(f"{path}: no decode rows")
    n_q = 0
    for p in sorted(prefixes):
        missing = [s for s in required if f"{p}.{s}" not in data]
        if missing:
            raise SystemExit(f"{path}: row `{p}` missing {missing}")
        if data[f"{p}.quant"] not in (0, 8):
            raise SystemExit(f"{path}: row `{p}` has quant "
                             f"{data[f'{p}.quant']} (want 0=f32 or 8=int8)")
        if data[f"{p}.quant"] == 0 and data[f"{p}.accept_delta"] != 0:
            raise SystemExit(f"{path}: f32 row `{p}` has nonzero "
                             "accept_delta (only quantized rows may "
                             "diverge from the reference)")
        n_q += data[f"{p}.quant"] != 0
    print(f"  {path}: decode schema OK ({len(prefixes)} rows, {n_q} quantized)")
if path.endswith("BENCH_serve.json"):
    # The serving benchmark has fixed schemas on top of the flat
    # name->number convention, scoped by row class:
    #   r<replicas>...   single-tenant rows: tail latency, throughput,
    #                    batching efficiency;
    #   mt.<tenant>.*    multi-tenant rows (serve-load --tenants):
    #                    offered vs sustained load, p99, sheds, and the
    #                    HLL distinct-user estimate (p99_vs_solo is
    #                    optional — only written when the solo baseline
    #                    ran);
    #   prom.*           label-aggregated metrics-registry totals —
    #                    free-form names, numeric-finite like all keys.
    # A run that stopped writing any required column is a regression,
    # not a formatting choice.
    serve_required = ["p50_ms", "p95_ms", "p99_ms", "sent_per_s",
                      "batch_fill", "padding_waste", "rejected"]
    mt_required = ["offered_rps", "sustained_rps", "p99_ms", "shed",
                   "distinct_users_est"]
    mt_optional = {"p99_vs_solo"}
    prefixes = {k.rsplit(".", 1)[0] for k in data if not k.startswith("prom.")}
    if not prefixes:
        raise SystemExit(f"{path}: no serve rows")
    n_mt = 0
    for p in sorted(prefixes):
        if p.startswith("mt."):
            n_mt += 1
            if p.count(".") != 1 or not p[3:]:
                raise SystemExit(f"{path}: malformed tenant row `{p}` "
                                 "(want mt.<tenant>.<col>; tenant ids "
                                 "must not contain dots)")
            required = mt_required
            cols = {k.rsplit(".", 1)[1] for k in data
                    if k.rsplit(".", 1)[0] == p}
            stray = cols - set(mt_required) - mt_optional
            if stray:
                raise SystemExit(f"{path}: tenant row `{p}` has unknown "
                                 f"columns {sorted(stray)}")
        else:
            required = serve_required
        missing = [s for s in required if f"{p}.{s}" not in data]
        if missing:
            raise SystemExit(f"{path}: row `{p}` missing {missing}")
    n_prom = sum(1 for k in data if k.startswith("prom."))
    print(f"  {path}: serve schema OK ({len(prefixes) - n_mt} serve rows, "
          f"{n_mt} tenant rows, {n_prom} prom totals)")
print(f"  {path}: OK ({len(data)} entries)")
EOF
    then :; else
        fail=1
    fi
done
[ "$found" = "1" ] || echo "  (no BENCH_*.json present yet — run the benches or serve-bench/serve-load)"

echo "== Prometheus dump sanity (results/metrics.prom)"
if [ -e results/metrics.prom ]; then
    # Required families are the acceptance hook: the serve scheduler,
    # coalescer and load-generator counters plus the HLL-backed
    # distinct-users gauge must all survive into the dump.
    if python3 scripts/check_prom.py results/metrics.prom \
        serve_submitted_total serve_completed_total serve_latency_ms \
        coalesce_deadline_flush_total loadgen_offered_total \
        serve_distinct_users; then
        :
    else
        fail=1
    fi
else
    echo "  (no results/metrics.prom yet — run serve-load --tenants or the tenant_serving tests)"
fi

echo "== Prometheus dump sanity (results/metrics_train.prom)"
if [ -e results/metrics_train.prom ]; then
    # Written by train-bench --chaos: the supervisor's recovery
    # counters must survive into the dump alongside the per-rank
    # training counters.
    if python3 scripts/check_prom.py results/metrics_train.prom \
        dist_supervisor_restarts_total dist_supervisor_failures_total \
        dist_supervisor_recovery_ms dist_supervisor_lost_steps \
        dist_steps_total; then
        :
    else
        fail=1
    fi
else
    echo "  (no results/metrics_train.prom yet — run train-bench --dist N --chaos)"
fi

if [ "$fail" != "0" ]; then
    echo "verify: FAILED"
    exit 1
fi
echo "verify: OK"
