#!/bin/sh
# Verification gate: build + tests + rustdoc + BENCH_*.json sanity.
#
#   ./scripts/verify.sh            # everything the machine can run
#   SKIP_CARGO=1 ./scripts/verify.sh   # docs/bench-JSON checks only
#
# The cargo stages run `cargo build --release`, `cargo test -q` (the
# tier-1 gate) and `cargo doc --no-deps` with warnings denied, so docs
# can't silently rot. The JSON stage validates every BENCH_*.json perf
# snapshot (micro/table3/decode) still parses and contains numbers, so
# benches can't silently rot either. On machines without a rust
# toolchain the cargo stages are reported as skipped and the script
# still fails on malformed bench files.

set -eu
cd "$(dirname "$0")/.."

fail=0

if [ "${SKIP_CARGO:-0}" != "1" ] && command -v cargo >/dev/null 2>&1; then
    echo "== cargo build --release"
    cargo build --release
    echo "== cargo test -q"
    cargo test -q
    echo "== cargo doc --no-deps (warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
else
    echo "== cargo not available (or SKIP_CARGO=1): skipping build/test/doc stages"
fi

echo "== BENCH_*.json sanity"
found=0
for f in BENCH_*.json; do
    [ -e "$f" ] || continue
    found=1
    if python3 - "$f" <<'EOF'
import json, math, sys
path = sys.argv[1]
with open(path) as fh:
    data = json.load(fh)
if not isinstance(data, dict) or not data:
    raise SystemExit(f"{path}: expected a non-empty object")
bad = [k for k, v in data.items()
       if not isinstance(v, (int, float)) or not math.isfinite(v)]
if bad:
    raise SystemExit(f"{path}: non-numeric/non-finite entries: {bad[:5]}")
print(f"  {path}: OK ({len(data)} entries)")
EOF
    then :; else
        fail=1
    fi
done
[ "$found" = "1" ] || echo "  (no BENCH_*.json present yet — run the benches or serve-bench)"

if [ "$fail" != "0" ]; then
    echo "verify: FAILED"
    exit 1
fi
echo "verify: OK"
